"""Batched lock-simulation sweeps on the xdes engine (one device program).

Two artifacts:

* ``fig3`` — the paper's Fig. 3 grid (4 regimes x 5 locks x 8 thread
  counts x seeds) as ONE ``jax.jit``-compiled call, summarized exactly like
  ``benchmarks.lockbench.fig3`` (avg throughput, ratio-to-optimum, PT-EXP)
  and checked against the paper's qualitative claims C2-C4.
* ``scenario`` — a beyond-paper sweep (default 200 scenarios x 5 locks =
  1000 configurations, one call per step-count bucket — see
  ``repro.core.xdes.plan_buckets``): random machines/workloads sampling
  the adaptive-spin design space, answering "which discipline wins where"
  and "how far from the per-scenario optimum is a blind static choice vs
  the mutable lock" — the experiment the sequential DES made impractical.
* ``oracle_grid`` — the SWS-oracle ablation (4 families x K x sws_max x
  scenarios, one call), consumed by ``benchmarks/oracle_ablation.py``
  which renders it into the phase-diagram report (see docs/oracles.md).
* ``discipline_grid`` — the full discipline x oracle diagram (every
  DISCIPLINE_ROW x every ORACLE_ROW x scenarios, one call), consumed by
  ``benchmarks/discipline_diagram.py`` (see docs/disciplines.md).
* ``workload_grid`` — the workload x discipline x oracle diagram (every
  WORKLOAD_ROW x every discipline variant x scenarios, one call),
  consumed by ``benchmarks/workload_diagram.py`` (see docs/workloads.md).
* ``arrival_grid`` — the open-loop arrival x offered-load x discipline
  diagram (every non-closed ARRIVAL_ROW x load fraction x discipline
  variant x scenarios, one call with per-request tail latency from the
  on-device histograms), consumed by ``benchmarks/arrival_diagram.py``
  (see docs/open_loop.md).
* ``fault_grid`` — the fault x discipline x oracle diagram (every
  FAULT_ROW x every discipline variant x scenarios, one call), the
  "which lock survives which failure mode" map consumed by
  ``benchmarks/fault_diagram.py`` (see docs/robustness.md).

Every one-shot batched call is gated by ``BatchResult.validate()``: a
non-finite engine output (poisoned cell) raises at the CLI with the
offending config named instead of propagating NaN into the diagrams
(the streaming path quarantines instead — see repro.core.stream).

Every batched call auto-shards its config axis over all visible devices
(``repro.core.xdes.simulate_batch(shard=...)``, ``shard_map`` through the
version-robust shim in ``repro/sharding/compat.py``) — on a multi-device
host the same entry points sweep 10-100k configurations.

Every grid also has a **streaming** mode (``stream=True`` / ``--stream``,
auto-on at >= :data:`STREAM_AUTO` configs): the grid is generated as raw
column arrays (``repro.configs.catalog.lock_*_columns``, no per-config
Python objects) and run chunk-by-chunk under a memory budget by
:func:`repro.core.stream.sweep_stream`, with the phase-diagram win
counts accumulated ON DEVICE (``CellReduce``) — the 100k-1M-config path
(docs/performance.md "Scaling sweeps").  ``refine_grid`` adds a
coarse->dense resolution-refinement sweep that re-samples dense lattices
only near phase boundaries at a fixed config budget.

    PYTHONPATH=src python -m benchmarks.sweep [--quick] [--backend pallas]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.configs.catalog import (LOCK_ARRIVAL_RHOS, LOCK_ARRIVALS,
                                   LOCK_CORES, LOCK_DISCIPLINE_SET,
                                   LOCK_DISCIPLINES, LOCK_FAULTS,
                                   LOCK_ORACLE_KS, LOCK_ORACLE_SWS_MAX,
                                   LOCK_ORACLES, LOCK_REGIMES, LOCK_SHORT,
                                   LOCK_THREADS, LOCK_WAKE, LOCK_WORKLOADS,
                                   LOCK_PARK_COSTS, _product_columns,
                                   lock_arrival_columns,
                                   lock_arrival_sweep, lock_arrival_variants,
                                   lock_discipline_columns,
                                   lock_discipline_sweep,
                                   lock_discipline_variants,
                                   lock_fault_columns, lock_fault_sweep,
                                   lock_fig3_grid, lock_oracle_columns,
                                   lock_oracle_sweep, lock_oracle_variants,
                                   lock_park_columns, lock_park_sweep,
                                   lock_scenario_columns,
                                   lock_scenario_sweep,
                                   lock_workload_columns, lock_workload_sweep,
                                   sample_scenario_columns)
from repro.core import stream as xstream
from repro.core import xdes

#: Config count at which the grids switch to the streaming path by
#: default (stream=None): below it the one-shot batched call is simpler
#: and the working set is small; above it chunking + on-device reduction
#: keep memory flat (see repro.core.stream).
STREAM_AUTO = 50_000

#: Structured quarantine report for streamed grids: configs whose engine
#: summaries came back non-finite are recorded here (and excluded from
#: the win-count reduction) instead of poisoning a phase diagram —
#: docs/robustness.md.  Only written when a sweep quarantined something.
FAILURES_PATH = os.path.join("reports", "sweep_failures.json")


def _variant_name(v: dict) -> str:
    """Display name of a (discipline, oracle) variant: *windowed* rows —
    the rows that actually read the oracle column (mutable, fissile) —
    carry a ``lock/oracle`` suffix; every other discipline appears bare
    (its oracle axis is pruned by ``lock_discipline_variants``)."""
    from repro.core.policy import POLICY_IDS, POLICY_ROW

    return (f"{v['lock']}/{v['oracle']}"
            if POLICY_ROW[POLICY_IDS[v["lock"]]].windowed else v["lock"])


# --------------------------------------------------------------------------
# Fig. 3 grid, batched
# --------------------------------------------------------------------------
def fig3_batched(target_cs: int = 250, seeds=(0, 1), backend: str = "ref",
                 verbose: bool = True) -> dict:
    configs = lock_fig3_grid(seeds=seeds)
    t0 = time.time()
    res = xdes.simulate_batch(configs, target_cs=target_cs,
                              backend=backend).validate("fig3")
    wall = time.time() - t0

    thr = res.throughput.reshape(len(LOCK_REGIMES), len(LOCK_DISCIPLINES),
                                 len(LOCK_THREADS), len(seeds)).mean(-1)
    cpu = res.sync_cpu_per_cs.reshape(thr.shape[0], thr.shape[1],
                                      thr.shape[2], len(seeds)).mean(-1)

    out: dict = {"meta": {"backend": backend, "n_configs": len(configs),
                          "n_steps": res.n_steps, "wall_s": round(wall, 2)}}
    for ri, regime in enumerate(LOCK_REGIMES):
        rows = {
            lock: [{"threads": int(tc), "throughput": float(thr[ri, li, ti]),
                    "sync_cpu_per_cs": float(cpu[ri, li, ti])}
                   for ti, tc in enumerate(LOCK_THREADS)]
            for li, lock in enumerate(LOCK_DISCIPLINES)
        }
        opt = thr[ri].max(axis=0)                  # optimum per thread count
        avg_opt = float(opt.mean())
        summary = {}
        for li, lock in enumerate(LOCK_DISCIPLINES):
            avg = float(thr[ri, li].mean())
            summary[lock] = {"avg_throughput": avg,
                             "ratio_to_opt": avg / avg_opt}
        pt_exp = 0.5 * (summary["ttas"]["avg_throughput"]
                        + summary["sleep"]["avg_throughput"])
        summary["pt-exp"] = {"avg_throughput": pt_exp,
                             "ratio_to_opt": pt_exp / avg_opt}
        out[regime] = {"rows": rows, "summary": summary}
        if verbose:
            print(f"\n=== {regime} (xdes, {backend}) ===")
            print(f"{'lock':>10} {'avg thr (cs/s)':>16} {'ratio':>7}")
            for lock in list(LOCK_DISCIPLINES) + ["pt-exp"]:
                s = summary[lock]
                print(f"{lock:>10} {s['avg_throughput']:16.0f} "
                      f"{s['ratio_to_opt']:7.3f}")

    out["claims"] = _check_claims(out)
    if verbose:
        print(f"\nfig3 batched: {len(configs)} configs x {res.n_steps} "
              f"steps in {wall:.1f}s -> claims {out['claims']}")
    return out


def _check_claims(f3: dict) -> dict:
    """The paper's qualitative orderings (C2-C4) on the batched results."""
    ss = f3["cs_short_ncs_short"]["summary"]
    ls = f3["cs_long_ncs_short"]["summary"]
    lo = f3["cs_short_ncs_long"]["summary"]
    # C2: short CS — mutable within ~12% of optimum and above PT-EXP.
    c2 = (ss["mutable"]["ratio_to_opt"] > ss["pt-exp"]["ratio_to_opt"]
          and ss["mutable"]["ratio_to_opt"] > 0.85)
    # C3: long CS — mutable within ~15% of optimum while spin CPU is cut
    # by >= 5x vs TTAS at 20 threads (checked on per-thread rows).
    rows = f3["cs_long_ncs_short"]["rows"]
    i20 = list(LOCK_THREADS).index(20)
    ttas_cpu = rows["ttas"][i20]["sync_cpu_per_cs"]
    mut_cpu = max(rows["mutable"][i20]["sync_cpu_per_cs"], 1e-12)
    c3 = (ls["mutable"]["ratio_to_opt"] > 0.8 and ttas_cpu / mut_cpu >= 5.0)
    # C4: low contention — every lock within ~12% of every other.
    ratios = [lo[l]["ratio_to_opt"] for l in LOCK_DISCIPLINES]
    c4 = min(ratios) > 0.85
    return {"C2": bool(c2), "C3": bool(c3), "C4": bool(c4),
            "ttas_over_mutable_cpu_at_20t": round(ttas_cpu / mut_cpu, 1)}


# --------------------------------------------------------------------------
# Beyond-paper scenario sweep
# --------------------------------------------------------------------------
def scenario(n_scenarios: int = 200, target_cs: int = 150,
             backend: str = "ref", seed: int = 0, bucket: bool = True,
             stream: bool | None = None, mem_mb: float | None = None,
             early_exit: bool | None = None, verbose: bool = True) -> dict:
    """``bucket=True`` groups the heterogeneous scenarios into power-of-two
    step-count buckets (:func:`repro.core.xdes.plan_buckets`) — one
    batched call per bucket instead of pinning every cell to the slowest
    scenario's scan length.  All five locks of a scenario share its
    planned step count, so per-scenario comparisons stay consistent.

    ``stream=True`` (auto at >= :data:`STREAM_AUTO` configs) feeds the
    grid as column arrays through :func:`repro.core.stream.sweep_stream`
    under the ``mem_mb`` memory budget, with the per-lock win counts
    accumulated on device."""
    locks = list(LOCK_DISCIPLINES)
    C = n_scenarios * len(locks)
    if stream is None:
        stream = C >= STREAM_AUTO
    t0 = time.time()
    if stream:
        cols = lock_scenario_columns(n_scenarios=n_scenarios, seed=seed,
                                     locks=locks)
        red = xstream.CellReduce(
            group=len(locks), cell_ids=np.zeros(n_scenarios, np.int32),
            n_cells=1)
        res = xstream.sweep_stream(cols, target_cs=target_cs,
                                   backend=backend, bucket_steps=bucket,
                                   reduce=red, mem_mb=mem_mb,
                                   early_exit=early_exit,
                                   failures_path=FAILURES_PATH)
        win_counts = res.wins[0]
    else:
        configs = lock_scenario_sweep(n_scenarios=n_scenarios, seed=seed,
                                      locks=locks)
        res = xdes.simulate_batch(configs, target_cs=target_cs,
                                  backend=backend, bucket_steps=bucket,
                                  early_exit=early_exit).validate("scenario")
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, len(locks))
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, len(locks))
    best = thr.max(axis=1)
    ratio = thr / np.maximum(best[:, None], 1e-30)
    if not stream:
        win = thr.argmax(axis=1)
        win_counts = np.asarray([(win == i).sum()
                                 for i in range(len(locks))])

    out = {
        "meta": {"backend": backend, "n_configs": C,
                 "n_steps": res.n_steps, "wall_s": round(wall, 2),
                 "streamed": bool(stream),
                 "configs_per_s": round(C / max(wall, 1e-9), 1)},
        "wins": {lock: int(win_counts[i])
                 for i, lock in enumerate(locks)},
        "mean_ratio_to_best": {lock: float(ratio[:, i].mean())
                               for i, lock in enumerate(locks)},
        "p10_ratio_to_best": {lock: float(np.percentile(ratio[:, i], 10))
                              for i, lock in enumerate(locks)},
        "mean_sync_cpu_per_cs_us": {lock: float(cpu[:, i].mean() * 1e6)
                                    for i, lock in enumerate(locks)},
    }
    if stream:
        out["meta"].update(chunk_size=res.chunk_size,
                           n_chunks=res.n_chunks,
                           budget_mb=round(res.budget_mb, 1))
    if verbose:
        how = (f"streamed in {res.n_chunks} chunk(s) of "
               f"<= {res.chunk_size}" if stream else "one-shot")
        print(f"\nscenario sweep: {C} configs x {res.n_steps} "
              f"steps ({how}) in {wall:.1f}s "
              f"({out['meta']['configs_per_s']} cfg/s)")
        print(f"{'lock':>10} {'wins':>6} {'mean ratio':>11} "
              f"{'p10 ratio':>10} {'cpu/cs (µs)':>12}")
        for i, lock in enumerate(locks):
            print(f"{lock:>10} {out['wins'][lock]:6d} "
                  f"{out['mean_ratio_to_best'][lock]:11.3f} "
                  f"{out['p10_ratio_to_best'][lock]:10.3f} "
                  f"{out['mean_sync_cpu_per_cs_us'][lock]:12.2f}")
    return out


# --------------------------------------------------------------------------
# Oracle-family ablation grid
# --------------------------------------------------------------------------
def _scenario_feats(sc_cols: dict) -> list[dict]:
    """Coarse workload features per scenario — the phase-diagram axes —
    from :func:`repro.configs.catalog.sample_scenario_columns` arrays
    (shared by the one-shot and streaming paths, which therefore bucket
    identically)."""
    return [{
        "cs": "short" if cs <= 1e-5 else "mid" if cs <= 1e-4 else "long",
        "sub": "under" if th <= co else "over",
        "wake": "fast" if wk <= 1e-5 else "slow",
    } for th, co, cs, wk in zip(sc_cols["threads"], sc_cols["cores"],
                                sc_cols["cs_hi"], sc_cols["wake"])]


def _phase_cells(keys: list[tuple]) -> tuple[list[tuple], np.ndarray]:
    """Order the distinct phase-cell keys and map each reduction group to
    its cell id — the ``CellReduce.cell_ids`` layout shared by the
    on-device (streamed) and host (one-shot) win accounting."""
    uniq = sorted(set(keys))
    kid = {k: i for i, k in enumerate(uniq)}
    return uniq, np.asarray([kid[k] for k in keys], np.int32)


def _host_wins(throughput, n_cells: int, cell_ids, group: int) -> np.ndarray:
    """Host twin of the streamed on-device accumulation: win counts per
    (phase cell, variant) from the per-config throughput columns."""
    win = np.asarray(throughput).reshape(-1, group).argmax(axis=1)
    wins = np.zeros((n_cells, group), np.int64)
    np.add.at(wins, (np.asarray(cell_ids), win), 1)
    return wins


def oracle_grid(n_scenarios: int = 200, target_cs: int = 150,
                backend: str = "ref", seed: int = 0,
                oracles=LOCK_ORACLES, ks=LOCK_ORACLE_KS,
                sws_maxes=LOCK_ORACLE_SWS_MAX, stream: bool | None = None,
                mem_mb: float | None = None,
                early_exit: bool | None = None,
                verbose: bool = True) -> dict:
    """The full ``(oracle, K, sws_max) x scenario`` product as ONE
    jit-compiled :func:`repro.core.xdes.simulate_batch` call (no per-cell
    Python loop) — or, with ``stream=True`` (auto at >=
    :data:`STREAM_AUTO` configs), chunk-by-chunk under a memory budget
    via :func:`repro.core.stream.sweep_stream` with the phase-cell win
    counts accumulated on device — summarized three ways:

    * per variant — wins, mean/p10 throughput ratio to the per-scenario
      best variant, spin CPU per CS;
    * per family — wins of its best-tuned variant and the ratio a
      per-scenario best tuning of that family achieves;
    * phase diagram — which family wins in each (CS-length x
      subscription x wake-latency) workload bucket, the "which oracle
      wins where" artifact rendered by ``benchmarks/oracle_ablation.py``.
    """
    variants = lock_oracle_variants(oracles, ks, sws_maxes)
    V = len(variants)
    C = n_scenarios * V
    if stream is None:
        stream = C >= STREAM_AUTO
    feats = _scenario_feats(sample_scenario_columns(n_scenarios, seed))
    uniq, cell_ids = _phase_cells(
        [(f["cs"], f["sub"], f["wake"]) for f in feats])
    t0 = time.time()
    if stream:
        cols = lock_oracle_columns(n_scenarios=n_scenarios, seed=seed,
                                   oracles=oracles, ks=ks,
                                   sws_maxes=sws_maxes)
        res = xstream.sweep_stream(
            cols, target_cs=target_cs, backend=backend, mem_mb=mem_mb,
            early_exit=early_exit, failures_path=FAILURES_PATH,
            reduce=xstream.CellReduce(V, cell_ids, len(uniq)))
        wins_cells = res.wins
    else:
        configs = lock_oracle_sweep(n_scenarios=n_scenarios, seed=seed,
                                    oracles=oracles, ks=ks,
                                    sws_maxes=sws_maxes)
        res = xdes.simulate_batch(
            configs, target_cs=target_cs, backend=backend,
            early_exit=early_exit).validate("oracle_grid")
        wins_cells = _host_wins(res.throughput, len(uniq), cell_ids, V)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, V)
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, V)
    sws = res.final_sws.reshape(n_scenarios, V)
    best = np.maximum(thr.max(axis=1), 1e-30)
    ratio = thr / best[:, None]
    win_v = wins_cells.sum(axis=0)

    def vname(v):
        m = "cores" if v["sws_max"] is None else v["sws_max"]
        return f"{v['oracle']}-k{v['k']}-m{m}"

    out_variants = [{
        "name": vname(v), "oracle": v["oracle"], "k": v["k"],
        "sws_max": v["sws_max"], "wins": int(win_v[i]),
        "mean_ratio_to_best": float(ratio[:, i].mean()),
        "p10_ratio_to_best": float(np.percentile(ratio[:, i], 10)),
        "mean_sync_cpu_per_cs_us": float(cpu[:, i].mean() * 1e6),
        "mean_final_sws": float(sws[:, i].mean()),
    } for i, v in enumerate(variants)]

    fam_names = list(dict.fromkeys(v["oracle"] for v in variants))
    fam_cols = {f: [i for i, v in enumerate(variants) if v["oracle"] == f]
                for f in fam_names}
    families = {f: {
        "wins": int(win_v[cols].sum()),
        # ratio achieved by the best tuning of this family per scenario
        "best_tuned_mean_ratio": float(ratio[:, cols].max(axis=1).mean()),
        "mean_sync_cpu_per_cs_us": float(cpu[:, cols].mean() * 1e6),
    } for f, cols in fam_cols.items()}

    phase = []
    for ci, (cs_b, sub_b, wake_b) in enumerate(uniq):
        counts = {f: int(wins_cells[ci, cols].sum())
                  for f, cols in fam_cols.items()}
        n = sum(counts.values())
        winner = max(counts, key=counts.get)
        phase.append({"cs": cs_b, "sub": sub_b, "wake": wake_b, "n": n,
                      "winner": winner,
                      "win_share": round(counts[winner] / n, 3),
                      "wins_by_family": counts})

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_variants": V, "n_configs": C,
                 "n_steps": res.n_steps, "wall_s": round(wall, 2),
                 "streamed": bool(stream),
                 "configs_per_s": round(C / max(wall, 1e-9), 1)},
        "variants": out_variants,
        "families": families,
        "phase": phase,
    }
    if stream:
        out["meta"].update(chunk_size=res.chunk_size,
                           n_chunks=res.n_chunks,
                           budget_mb=round(res.budget_mb, 1))
    if verbose:
        print(f"\noracle grid: {C} configs ({n_scenarios} "
              f"scenarios x {V} variants) x {res.n_steps} steps "
              f"in {wall:.1f}s ({out['meta']['configs_per_s']} cfg/s)")
        print(f"{'family':>9} {'wins':>5} {'best-tuned ratio':>17} "
              f"{'cpu/cs (µs)':>12}")
        for f, row in families.items():
            print(f"{f:>9} {row['wins']:5d} "
                  f"{row['best_tuned_mean_ratio']:17.3f} "
                  f"{row['mean_sync_cpu_per_cs_us']:12.2f}")
    return out


# --------------------------------------------------------------------------
# Discipline x oracle diagram grid
# --------------------------------------------------------------------------
def discipline_grid(n_scenarios: int = 200, target_cs: int = 150,
                    backend: str = "ref", seed: int = 0,
                    disciplines=LOCK_DISCIPLINE_SET, oracles=LOCK_ORACLES,
                    shard: bool | None = None, stream: bool | None = None,
                    mem_mb: float | None = None,
                    early_exit: bool | None = None,
                    verbose: bool = True) -> dict:
    """The full ``(discipline, oracle) x scenario`` product — every row of
    ``DISCIPLINE_ROWS`` crossed with every ``ORACLE_ROWS`` family — as ONE
    (sharded) jit-compiled :func:`repro.core.xdes.simulate_batch` call —
    or, with ``stream=True`` (auto at >= :data:`STREAM_AUTO` configs),
    chunk-by-chunk under a memory budget via
    :func:`repro.core.stream.sweep_stream` with phase-cell win counts
    accumulated on device — summarized three ways:

    * per variant — wins, mean/p10 throughput ratio to the per-scenario
      best variant, spin CPU per CS, fairness spread;
    * per discipline — wins of its best variant and the ratio its
      best-oracle tuning achieves per scenario;
    * phase diagram — which (discipline, oracle) wins in each (CS-length
      x subscription x wake-latency) workload bucket: the "which lock
      wins where" artifact rendered by ``benchmarks/discipline_diagram.py``.
    """
    variants = lock_discipline_variants(disciplines, oracles)
    V = len(variants)
    C = n_scenarios * V
    if stream is None:
        stream = C >= STREAM_AUTO
    feats = _scenario_feats(sample_scenario_columns(n_scenarios, seed))
    uniq, cell_ids = _phase_cells(
        [(f["cs"], f["sub"], f["wake"]) for f in feats])
    t0 = time.time()
    if stream:
        cols = lock_discipline_columns(n_scenarios=n_scenarios, seed=seed,
                                       disciplines=disciplines,
                                       oracles=oracles)
        res = xstream.sweep_stream(
            cols, target_cs=target_cs, backend=backend, shard=shard,
            mem_mb=mem_mb, early_exit=early_exit,
            failures_path=FAILURES_PATH,
            reduce=xstream.CellReduce(V, cell_ids, len(uniq)))
        wins_cells = res.wins
    else:
        configs = lock_discipline_sweep(n_scenarios=n_scenarios, seed=seed,
                                        disciplines=disciplines,
                                        oracles=oracles)
        res = xdes.simulate_batch(
            configs, target_cs=target_cs, backend=backend, shard=shard,
            early_exit=early_exit).validate("discipline_grid")
        wins_cells = _host_wins(res.throughput, len(uniq), cell_ids, V)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, V)
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, V)
    best = np.maximum(thr.max(axis=1), 1e-30)
    ratio = thr / best[:, None]
    win_v = wins_cells.sum(axis=0)

    vname = _variant_name

    out_variants = [{
        "name": vname(v), "lock": v["lock"], "oracle": v["oracle"],
        "wins": int(win_v[i]),
        "mean_ratio_to_best": float(ratio[:, i].mean()),
        "p10_ratio_to_best": float(np.percentile(ratio[:, i], 10)),
        "mean_sync_cpu_per_cs_us": float(cpu[:, i].mean() * 1e6),
    } for i, v in enumerate(variants)]

    disc_names = list(dict.fromkeys(v["lock"] for v in variants))
    disc_cols = {d: [i for i, v in enumerate(variants) if v["lock"] == d]
                 for d in disc_names}
    by_discipline = {d: {
        "wins": int(win_v[cols].sum()),
        "best_variant_mean_ratio": float(ratio[:, cols].max(axis=1).mean()),
        "mean_sync_cpu_per_cs_us": float(cpu[:, cols].mean() * 1e6),
    } for d, cols in disc_cols.items()}

    variant_names = [vname(v) for v in variants]
    phase = []
    for ci, (cs_b, sub_b, wake_b) in enumerate(uniq):
        counts = {variant_names[i]: int(wins_cells[ci, i])
                  for i in range(V) if wins_cells[ci, i]}
        n = sum(counts.values())
        winner = max(counts, key=counts.get)
        phase.append({"cs": cs_b, "sub": sub_b, "wake": wake_b, "n": n,
                      "winner": winner,
                      "win_share": round(counts[winner] / n, 3),
                      "wins_by_variant": counts})

    import jax

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_variants": V, "n_configs": C,
                 "n_steps": res.n_steps, "wall_s": round(wall, 2),
                 "n_devices": len(jax.devices()),
                 "sharded": bool(shard) if shard is not None
                 else len(jax.devices()) > 1,
                 "streamed": bool(stream),
                 "configs_per_s": round(C / max(wall, 1e-9), 1)},
        "variants": out_variants,
        "disciplines": by_discipline,
        "phase": phase,
    }
    if stream:
        out["meta"].update(chunk_size=res.chunk_size,
                           n_chunks=res.n_chunks,
                           budget_mb=round(res.budget_mb, 1))
    if verbose:
        print(f"\ndiscipline grid: {C} configs ({n_scenarios} "
              f"scenarios x {V} variants) x {res.n_steps} steps in "
              f"{wall:.1f}s on {out['meta']['n_devices']} device(s) "
              f"({out['meta']['configs_per_s']} cfg/s)")
        print(f"{'discipline':>10} {'wins':>5} {'best-variant ratio':>19} "
              f"{'cpu/cs (µs)':>12}")
        for d, row in by_discipline.items():
            print(f"{d:>10} {row['wins']:5d} "
                  f"{row['best_variant_mean_ratio']:19.3f} "
                  f"{row['mean_sync_cpu_per_cs_us']:12.2f}")
    return out


# --------------------------------------------------------------------------
# Workload x discipline x oracle diagram grid
# --------------------------------------------------------------------------
def workload_grid(n_scenarios: int = 100, target_cs: int = 150,
                  backend: str = "ref", seed: int = 0,
                  workloads=LOCK_WORKLOADS,
                  disciplines=LOCK_DISCIPLINE_SET, oracles=LOCK_ORACLES,
                  shard: bool | None = None, stream: bool | None = None,
                  mem_mb: float | None = None,
                  early_exit: bool | None = None,
                  verbose: bool = True) -> dict:
    """The full ``workload x (discipline, oracle) x scenario`` product —
    every row of ``WORKLOAD_ROWS`` crossed with every discipline-diagram
    variant — as ONE (sharded) jit-compiled
    :func:`repro.core.xdes.simulate_batch` call, summarized three ways:

    * per (workload, variant) — wins, mean/p10 throughput ratio to the
      per-(scenario, workload) best variant, spin CPU per CS;
    * per workload — which discipline wins how often under that hold-time
      model, and each discipline's best-variant mean ratio;
    * phase diagram — which (discipline, oracle) wins in each
      (workload x CS-length x subscription) bucket: the "which lock wins
      under which workload" artifact rendered by
      ``benchmarks/workload_diagram.py``.

    The per-scenario best is taken *within* a workload, so a variant is
    judged against the other locks under the same workload — never
    against an easier workload's throughput.  With ``stream=True`` (auto
    at >= :data:`STREAM_AUTO` configs) the sweep runs chunk-by-chunk via
    :func:`repro.core.stream.sweep_stream`; each ``(scenario, workload)``
    slice of ``V`` variants is one reduction group, so the on-device
    argmax is the same within-workload contest.
    """
    disc_variants = lock_discipline_variants(disciplines, oracles)
    W, V = len(workloads), len(disc_variants)
    C = n_scenarios * W * V
    if stream is None:
        stream = C >= STREAM_AUTO
    feats = _scenario_feats(sample_scenario_columns(n_scenarios, seed))
    # One phase key per (scenario, workload) group of V variants.
    uniq, cell_ids = _phase_cells(
        [(w, f["cs"], f["sub"]) for f in feats for w in workloads])
    t0 = time.time()
    if stream:
        cols = lock_workload_columns(n_scenarios=n_scenarios, seed=seed,
                                     workloads=workloads,
                                     disciplines=disciplines,
                                     oracles=oracles)
        res = xstream.sweep_stream(
            cols, target_cs=target_cs, backend=backend, shard=shard,
            mem_mb=mem_mb, early_exit=early_exit,
            failures_path=FAILURES_PATH,
            reduce=xstream.CellReduce(V, cell_ids, len(uniq)))
        wins_cells = res.wins
    else:
        configs = lock_workload_sweep(n_scenarios=n_scenarios, seed=seed,
                                      workloads=workloads,
                                      disciplines=disciplines,
                                      oracles=oracles)
        res = xdes.simulate_batch(
            configs, target_cs=target_cs, backend=backend, shard=shard,
            early_exit=early_exit).validate("workload_grid")
        wins_cells = _host_wins(res.throughput, len(uniq), cell_ids, V)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, W, V)
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, W, V)
    best = np.maximum(thr.max(axis=2), 1e-30)          # (S, W)
    ratio = thr / best[..., None]
    # per-(workload, variant) win counts from the phase-cell matrix:
    # every (scenario, workload) group maps to exactly one cell whose key
    # starts with that workload, so summing cells by workload recovers
    # the within-workload contest.
    cell_w = np.asarray([list(workloads).index(k[0]) for k in uniq])
    win_wv = np.zeros((W, V), np.int64)
    np.add.at(win_wv, cell_w, wins_cells)

    vname = _variant_name

    variant_names = [vname(v) for v in disc_variants]
    out_variants = [{
        "workload": w, "name": variant_names[i],
        "lock": disc_variants[i]["lock"],
        "oracle": disc_variants[i]["oracle"],
        "wins": int(win_wv[wi, i]),
        "mean_ratio_to_best": float(ratio[:, wi, i].mean()),
        "p10_ratio_to_best": float(np.percentile(ratio[:, wi, i], 10)),
        "mean_sync_cpu_per_cs_us": float(cpu[:, wi, i].mean() * 1e6),
    } for wi, w in enumerate(workloads) for i in range(V)]

    disc_names = list(dict.fromkeys(v["lock"] for v in disc_variants))
    disc_cols = {d: [i for i, v in enumerate(disc_variants)
                     if v["lock"] == d] for d in disc_names}
    by_workload = {}
    for wi, w in enumerate(workloads):
        by_workload[w] = {d: {
            "wins": int(win_wv[wi, cols].sum()),
            "best_variant_mean_ratio":
                float(ratio[:, wi, cols].max(axis=1).mean()),
            "mean_sync_cpu_per_cs_us":
                float(cpu[:, wi, cols].mean() * 1e6),
        } for d, cols in disc_cols.items()}

    phase = []
    order = sorted(range(len(uniq)),
                   key=lambda ci: (list(workloads).index(uniq[ci][0]),
                                   uniq[ci][1:]))
    for ci in order:
        w, cs_b, sub_b = uniq[ci]
        counts = {variant_names[i]: int(wins_cells[ci, i])
                  for i in range(V) if wins_cells[ci, i]}
        n = sum(counts.values())
        winner = max(counts, key=counts.get)
        phase.append({"workload": w, "cs": cs_b, "sub": sub_b, "n": n,
                      "winner": winner,
                      "win_share": round(counts[winner] / n, 3),
                      "wins_by_variant": counts})

    import jax

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_workloads": W, "n_variants": V,
                 "n_configs": C, "n_steps": res.n_steps,
                 "wall_s": round(wall, 2),
                 "n_devices": len(jax.devices()),
                 "sharded": bool(shard) if shard is not None
                 else len(jax.devices()) > 1,
                 "streamed": bool(stream),
                 "configs_per_s": round(C / max(wall, 1e-9), 1),
                 "workloads": list(workloads),
                 "variant_names": variant_names},
        "variants": out_variants,
        "workloads": by_workload,
        "phase": phase,
    }
    if stream:
        out["meta"].update(chunk_size=res.chunk_size,
                           n_chunks=res.n_chunks,
                           budget_mb=round(res.budget_mb, 1))
    if verbose:
        print(f"\nworkload grid: {C} configs ({n_scenarios} "
              f"scenarios x {W} workloads x {V} variants) x {res.n_steps} "
              f"steps in {wall:.1f}s on {out['meta']['n_devices']} "
              f"device(s) ({out['meta']['configs_per_s']} cfg/s)")
        for w in workloads:
            rows = by_workload[w]
            top = max(rows, key=lambda d: rows[d]["wins"])
            print(f"{w:>9}: top discipline {top} "
                  f"({rows[top]['wins']}/{n_scenarios} wins); "
                  + " ".join(f"{d}:{r['wins']}" for d, r in rows.items()))
    return out


# --------------------------------------------------------------------------
# Arrival-rate x discipline diagram grid (open loop)
# --------------------------------------------------------------------------
def arrival_grid(n_scenarios: int = 50, target_cs: int = 150,
                 backend: str = "ref", seed: int = 0,
                 arrivals=LOCK_ARRIVALS, rhos=LOCK_ARRIVAL_RHOS,
                 disciplines=LOCK_DISCIPLINE_SET, oracles=LOCK_ORACLES,
                 shard: bool | None = None, stream: bool | None = None,
                 mem_mb: float | None = None,
                 early_exit: bool | None = None,
                 verbose: bool = True) -> dict:
    """The full ``arrival x load x (discipline, oracle) x scenario``
    product — every open-loop ``ARRIVAL_ROW`` at every offered-load
    fraction ``rho`` of the scenario's service capacity — as ONE
    (sharded) jit-compiled :func:`repro.core.xdes.simulate_batch` call
    with the open-loop engine on, reporting per-request tail latency
    (p50/p95/p99 from the on-device histograms), SLO-violation fraction,
    and shed fraction per config.  Summarized three ways:

    * per (arrival, rho, variant) — throughput wins, mean p95/p99, mean
      SLO-violation and shed fractions;
    * per discipline — wins and best-variant tail latency per cell;
    * phase diagram — which (discipline, oracle) wins each
      ``(arrival row x offered load)`` cell, by throughput (the
      on-device :class:`repro.core.stream.CellReduce` winner) AND by p95
      tail latency (host reduction of the per-config histograms): the
      "which lock serves traffic best" artifact rendered by
      ``benchmarks/arrival_diagram.py``.

    Row order is scenario-major, then arrival, then rho, then variant —
    reshape to ``(n_scenarios, n_arrivals, n_rhos, n_variants)``.
    Scenarios follow the :func:`sample_scenarios` seed contract, so every
    cell sees the same machines scenario-by-scenario."""
    disc_variants = lock_discipline_variants(disciplines, oracles)
    A, R, V = len(arrivals), len(rhos), len(disc_variants)
    C = n_scenarios * A * R * V
    if stream is None:
        stream = C >= STREAM_AUTO
    # One phase cell per (arrival row, rho): the diagram's axes.  Every
    # (scenario, arrival, rho) slice of V variants is one reduction group.
    uniq, cell_ids = _phase_cells(
        [(a, r) for _ in range(n_scenarios) for a in arrivals
         for r in rhos])
    t0 = time.time()
    if stream:
        cols = lock_arrival_columns(n_scenarios=n_scenarios, seed=seed,
                                    arrivals=arrivals, rhos=rhos,
                                    disciplines=disciplines,
                                    oracles=oracles)
        res = xstream.sweep_stream(
            cols, target_cs=target_cs, backend=backend, shard=shard,
            mem_mb=mem_mb, early_exit=early_exit,
            failures_path=FAILURES_PATH,
            reduce=xstream.CellReduce(V, cell_ids, len(uniq)))
        wins_cells = res.wins
    else:
        configs = lock_arrival_sweep(n_scenarios=n_scenarios, seed=seed,
                                     arrivals=arrivals, rhos=rhos,
                                     disciplines=disciplines,
                                     oracles=oracles)
        res = xdes.simulate_batch(
            configs, target_cs=target_cs, backend=backend, shard=shard,
            early_exit=early_exit).validate("arrival_grid")
        wins_cells = _host_wins(res.throughput, len(uniq), cell_ids, V)
    wall = time.time() - t0

    shape = (n_scenarios, A, R, V)
    p50 = res.p50.reshape(shape)
    p95 = res.p95.reshape(shape)
    p99 = res.p99.reshape(shape)
    slo_frac = res.slo_frac.reshape(shape)
    arrived = res.arrived.reshape(shape)
    shed_frac = (res.shed.reshape(shape)
                 / np.maximum(arrived, 1).astype(np.float64))
    # host-side tail-latency winner per (scenario, arrival, rho) group:
    # lowest p95 among variants that departed anything (NaN = no service,
    # never wins while any variant served traffic).
    p95_rank = np.where(np.isnan(p95), np.inf, p95)
    lat_win = p95_rank.reshape(-1, V).argmin(axis=1)
    lat_wins_cells = np.zeros((len(uniq), V), np.int64)
    np.add.at(lat_wins_cells, (np.asarray(cell_ids), lat_win), 1)

    vname = _variant_name

    variant_names = [vname(v) for v in disc_variants]
    cell_of = {k: i for i, k in enumerate(uniq)}
    win_thr = np.asarray(wins_cells)

    out_variants = [{
        "arrival": a, "rho": r, "name": variant_names[i],
        "lock": disc_variants[i]["lock"],
        "oracle": disc_variants[i]["oracle"],
        "wins": int(win_thr[cell_of[(a, r)], i]),
        "lat_wins": int(lat_wins_cells[cell_of[(a, r)], i]),
        "mean_p50_us": float(np.nanmean(p50[:, ai, ri, i]) * 1e6),
        "mean_p95_us": float(np.nanmean(p95[:, ai, ri, i]) * 1e6),
        "mean_p99_us": float(np.nanmean(p99[:, ai, ri, i]) * 1e6),
        "mean_slo_frac": float(np.nanmean(slo_frac[:, ai, ri, i])),
        "mean_shed_frac": float(shed_frac[:, ai, ri, i].mean()),
    } for ai, a in enumerate(arrivals) for ri, r in enumerate(rhos)
        for i in range(V)]

    phase = []
    for ai, a in enumerate(arrivals):
        for ri, r in enumerate(rhos):
            ci = cell_of[(a, r)]
            counts = {variant_names[i]: int(win_thr[ci, i])
                      for i in range(V) if win_thr[ci, i]}
            lcounts = {variant_names[i]: int(lat_wins_cells[ci, i])
                       for i in range(V) if lat_wins_cells[ci, i]}
            n = sum(counts.values())
            winner = max(counts, key=counts.get)
            lat_winner = max(lcounts, key=lcounts.get)
            phase.append({
                "arrival": a, "rho": r, "n": n,
                "winner": winner,
                "win_share": round(counts[winner] / n, 3),
                "lat_winner": lat_winner,
                "lat_win_share": round(lcounts[lat_winner]
                                       / max(sum(lcounts.values()), 1), 3),
                "mean_slo_frac": float(np.nanmean(slo_frac[:, ai, ri, :])),
                "mean_shed_frac": float(shed_frac[:, ai, ri, :].mean()),
                "wins_by_variant": counts,
                "lat_wins_by_variant": lcounts,
            })

    import jax

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_arrivals": A, "n_rhos": R, "n_variants": V,
                 "n_configs": C, "n_steps": res.n_steps,
                 "wall_s": round(wall, 2),
                 "n_devices": len(jax.devices()),
                 "sharded": bool(shard) if shard is not None
                 else len(jax.devices()) > 1,
                 "streamed": bool(stream),
                 "configs_per_s": round(C / max(wall, 1e-9), 1),
                 "arrivals": list(arrivals), "rhos": list(rhos),
                 "variant_names": variant_names},
        "variants": out_variants,
        "phase": phase,
    }
    if stream:
        out["meta"].update(chunk_size=res.chunk_size,
                           n_chunks=res.n_chunks,
                           budget_mb=round(res.budget_mb, 1))
    if verbose:
        print(f"\narrival grid: {C} configs ({n_scenarios} scenarios x "
              f"{A} arrivals x {R} loads x {V} variants) x {res.n_steps} "
              f"steps in {wall:.1f}s on {out['meta']['n_devices']} "
              f"device(s) ({out['meta']['configs_per_s']} cfg/s)")
        for cell in phase:
            print(f"{cell['arrival']:>8} rho={cell['rho']:<4} "
                  f"thr-winner {cell['winner']:<16} "
                  f"p95-winner {cell['lat_winner']:<16} "
                  f"slo-viol {cell['mean_slo_frac']:.3f} "
                  f"shed {cell['mean_shed_frac']:.3f}")
    return out


# --------------------------------------------------------------------------
# Fault x discipline x oracle diagram grid
# --------------------------------------------------------------------------
def fault_grid(n_scenarios: int = 100, target_cs: int = 150,
               backend: str = "ref", seed: int = 0,
               faults=LOCK_FAULTS,
               disciplines=LOCK_DISCIPLINE_SET, oracles=LOCK_ORACLES,
               shard: bool | None = None, stream: bool | None = None,
               mem_mb: float | None = None,
               early_exit: bool | None = None,
               verbose: bool = True) -> dict:
    """The full ``fault x (discipline, oracle) x scenario`` product —
    every row of ``FAULT_ROWS`` (benign baseline, lock-holder preemption,
    CPU oversubscription, lost wake-ups, timer jitter — see
    docs/robustness.md) crossed with every discipline-diagram variant —
    as ONE (sharded) jit-compiled :func:`repro.core.xdes.simulate_batch`
    call, summarized three ways:

    * per (fault, variant) — wins, mean/p10 throughput ratio to the
      per-(scenario, fault) best variant, spin CPU per CS, and the mean
      throughput retained vs the same variant on the ``none`` row (the
      degradation axis the benign diagrams cannot show);
    * per fault — which discipline wins how often under that failure
      mode, each discipline's best-variant ratio and retention;
    * phase diagram — which (discipline, oracle) wins in each
      (fault x CS-length x subscription) bucket: the "which lock
      survives which failure mode" artifact rendered by
      ``benchmarks/fault_diagram.py``.

    The per-scenario best is taken *within* a fault row, so a variant is
    judged against the other locks under the same interference — never
    against the benign machine's throughput.  Scenarios follow the
    :func:`sample_scenarios` seed contract, so the ``none`` row IS the
    discipline diagram's machine scenario-by-scenario.  With
    ``stream=True`` (auto at >= :data:`STREAM_AUTO` configs) the sweep
    runs chunk-by-chunk via :func:`repro.core.stream.sweep_stream`; each
    ``(scenario, fault)`` slice of ``V`` variants is one reduction
    group, so the on-device argmax is the same within-fault contest.
    """
    disc_variants = lock_discipline_variants(disciplines, oracles)
    F, V = len(faults), len(disc_variants)
    C = n_scenarios * F * V
    if stream is None:
        stream = C >= STREAM_AUTO
    feats = _scenario_feats(sample_scenario_columns(n_scenarios, seed))
    # One phase key per (scenario, fault) group of V variants.
    uniq, cell_ids = _phase_cells(
        [(fl, ft["cs"], ft["sub"]) for ft in feats for fl in faults])
    t0 = time.time()
    if stream:
        cols = lock_fault_columns(n_scenarios=n_scenarios, seed=seed,
                                  faults=faults, disciplines=disciplines,
                                  oracles=oracles)
        res = xstream.sweep_stream(
            cols, target_cs=target_cs, backend=backend, shard=shard,
            mem_mb=mem_mb, early_exit=early_exit,
            failures_path=FAILURES_PATH,
            reduce=xstream.CellReduce(V, cell_ids, len(uniq)))
        wins_cells = res.wins
    else:
        configs = lock_fault_sweep(n_scenarios=n_scenarios, seed=seed,
                                   faults=faults, disciplines=disciplines,
                                   oracles=oracles)
        res = xdes.simulate_batch(
            configs, target_cs=target_cs, backend=backend, shard=shard,
            early_exit=early_exit).validate("fault_grid")
        wins_cells = _host_wins(res.throughput, len(uniq), cell_ids, V)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, F, V)
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, F, V)
    best = np.maximum(thr.max(axis=2), 1e-30)          # (S, F)
    ratio = thr / best[..., None]
    # Throughput retained vs the benign row, same scenario and variant —
    # the robustness ordinate (1.0 = unaffected).  Only defined when the
    # grid includes the "none" row.
    retained = None
    if "none" in faults:
        base = np.maximum(thr[:, list(faults).index("none"), :], 1e-30)
        retained = thr / base[:, None, :]
    # per-(fault, variant) win counts from the phase-cell matrix: every
    # (scenario, fault) group maps to exactly one cell whose key starts
    # with that fault, so summing cells by fault recovers the
    # within-fault contest.
    cell_f = np.asarray([list(faults).index(k[0]) for k in uniq])
    win_fv = np.zeros((F, V), np.int64)
    np.add.at(win_fv, cell_f, wins_cells)

    vname = _variant_name

    variant_names = [vname(v) for v in disc_variants]
    out_variants = [{
        "fault": fl, "name": variant_names[i],
        "lock": disc_variants[i]["lock"],
        "oracle": disc_variants[i]["oracle"],
        "wins": int(win_fv[fi, i]),
        "mean_ratio_to_best": float(ratio[:, fi, i].mean()),
        "p10_ratio_to_best": float(np.percentile(ratio[:, fi, i], 10)),
        "mean_retained_vs_none": (float(retained[:, fi, i].mean())
                                  if retained is not None else None),
        "mean_sync_cpu_per_cs_us": float(cpu[:, fi, i].mean() * 1e6),
    } for fi, fl in enumerate(faults) for i in range(V)]

    disc_names = list(dict.fromkeys(v["lock"] for v in disc_variants))
    disc_cols = {d: [i for i, v in enumerate(disc_variants)
                     if v["lock"] == d] for d in disc_names}
    by_fault = {}
    for fi, fl in enumerate(faults):
        by_fault[fl] = {d: {
            "wins": int(win_fv[fi, cols].sum()),
            "best_variant_mean_ratio":
                float(ratio[:, fi, cols].max(axis=1).mean()),
            "mean_retained_vs_none":
                (float(retained[:, fi, cols].mean())
                 if retained is not None else None),
            "mean_sync_cpu_per_cs_us":
                float(cpu[:, fi, cols].mean() * 1e6),
        } for d, cols in disc_cols.items()}

    phase = []
    order = sorted(range(len(uniq)),
                   key=lambda ci: (list(faults).index(uniq[ci][0]),
                                   uniq[ci][1:]))
    for ci in order:
        fl, cs_b, sub_b = uniq[ci]
        counts = {variant_names[i]: int(wins_cells[ci, i])
                  for i in range(V) if wins_cells[ci, i]}
        n = sum(counts.values())
        winner = max(counts, key=counts.get)
        phase.append({"fault": fl, "cs": cs_b, "sub": sub_b, "n": n,
                      "winner": winner,
                      "win_share": round(counts[winner] / n, 3),
                      "wins_by_variant": counts})

    import jax

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_faults": F, "n_variants": V,
                 "n_configs": C, "n_steps": res.n_steps,
                 "wall_s": round(wall, 2),
                 "n_devices": len(jax.devices()),
                 "sharded": bool(shard) if shard is not None
                 else len(jax.devices()) > 1,
                 "streamed": bool(stream),
                 "configs_per_s": round(C / max(wall, 1e-9), 1),
                 "faults": list(faults),
                 "variant_names": variant_names},
        "variants": out_variants,
        "faults": by_fault,
        "phase": phase,
    }
    if stream:
        out["meta"].update(chunk_size=res.chunk_size,
                           n_chunks=res.n_chunks,
                           budget_mb=round(res.budget_mb, 1))
    if verbose:
        print(f"\nfault grid: {C} configs ({n_scenarios} "
              f"scenarios x {F} faults x {V} variants) x {res.n_steps} "
              f"steps in {wall:.1f}s on {out['meta']['n_devices']} "
              f"device(s) ({out['meta']['configs_per_s']} cfg/s)")
        for fl in faults:
            rows = by_fault[fl]
            top = max(rows, key=lambda d: rows[d]["wins"])
            print(f"{fl:>9}: top discipline {top} "
                  f"({rows[top]['wins']}/{n_scenarios} wins); "
                  + " ".join(f"{d}:{r['wins']}" for d, r in rows.items()))
    return out


# --------------------------------------------------------------------------
# Park-cost x discipline x oracle diagram grid (M:N environments)
# --------------------------------------------------------------------------
def park_grid(n_scenarios: int = 50, target_cs: int = 150,
              backend: str = "ref", seed: int = 0,
              park_costs=LOCK_PARK_COSTS,
              disciplines=LOCK_DISCIPLINE_SET, oracles=LOCK_ORACLES,
              shard: bool | None = None, stream: bool | None = None,
              mem_mb: float | None = None,
              early_exit: bool | None = None,
              verbose: bool = True) -> dict:
    """The full ``park_cost x (discipline, oracle) x scenario`` product —
    the M:N lightweight-thread environment axis (``SimConfig.park_cost``
    scaling the park/unpark round trip across three orders of magnitude)
    crossed with every discipline-diagram variant — as ONE (sharded)
    jit-compiled :func:`repro.core.xdes.simulate_batch` call, summarized
    three ways:

    * per (park_cost, variant) — wins, mean/p10 throughput ratio to the
      per-(scenario, park_cost) best variant, spin CPU per CS, and the
      throughput retained vs the same variant at ``park_cost=1`` (how
      hard the environment re-prices each sleep-leaning row);
    * per park_cost — which discipline wins how often in that
      environment;
    * phase diagram — which (discipline, oracle) wins in each
      (park_cost x CS-length x subscription) bucket: the "when is
      parking worth it" artifact rendered by ``benchmarks/park_diagram``.

    The per-scenario best is taken *within* a park-cost slice, so a
    variant is judged against the other locks in the same environment.
    Scenarios follow the :func:`sample_scenarios` seed contract, so the
    ``park_cost=1`` slice IS the discipline diagram's machine
    scenario-by-scenario."""
    disc_variants = lock_discipline_variants(disciplines, oracles)
    K, V = len(park_costs), len(disc_variants)
    C = n_scenarios * K * V
    if stream is None:
        stream = C >= STREAM_AUTO
    feats = _scenario_feats(sample_scenario_columns(n_scenarios, seed))
    # One phase key per (scenario, park_cost) group of V variants.
    uniq, cell_ids = _phase_cells(
        [(p, ft["cs"], ft["sub"]) for ft in feats for p in park_costs])
    t0 = time.time()
    if stream:
        cols = lock_park_columns(n_scenarios=n_scenarios, seed=seed,
                                 park_costs=park_costs,
                                 disciplines=disciplines, oracles=oracles)
        res = xstream.sweep_stream(
            cols, target_cs=target_cs, backend=backend, shard=shard,
            mem_mb=mem_mb, early_exit=early_exit,
            failures_path=FAILURES_PATH,
            reduce=xstream.CellReduce(V, cell_ids, len(uniq)))
        wins_cells = res.wins
    else:
        configs = lock_park_sweep(n_scenarios=n_scenarios, seed=seed,
                                  park_costs=park_costs,
                                  disciplines=disciplines, oracles=oracles)
        res = xdes.simulate_batch(
            configs, target_cs=target_cs, backend=backend, shard=shard,
            early_exit=early_exit).validate("park_grid")
        wins_cells = _host_wins(res.throughput, len(uniq), cell_ids, V)
    wall = time.time() - t0

    thr = res.throughput.reshape(n_scenarios, K, V)
    cpu = res.sync_cpu_per_cs.reshape(n_scenarios, K, V)
    best = np.maximum(thr.max(axis=2), 1e-30)          # (S, K)
    ratio = thr / best[..., None]
    # Throughput retained vs the park_cost=1 baseline, same scenario and
    # variant — the re-pricing ordinate (only when the grid includes 1.0).
    retained = None
    if 1.0 in park_costs:
        base = np.maximum(thr[:, list(park_costs).index(1.0), :], 1e-30)
        retained = thr / base[:, None, :]
    cell_k = np.asarray([list(park_costs).index(k[0]) for k in uniq])
    win_kv = np.zeros((K, V), np.int64)
    np.add.at(win_kv, cell_k, wins_cells)

    vname = _variant_name

    variant_names = [vname(v) for v in disc_variants]
    out_variants = [{
        "park_cost": p, "name": variant_names[i],
        "lock": disc_variants[i]["lock"],
        "oracle": disc_variants[i]["oracle"],
        "wins": int(win_kv[ki, i]),
        "mean_ratio_to_best": float(ratio[:, ki, i].mean()),
        "p10_ratio_to_best": float(np.percentile(ratio[:, ki, i], 10)),
        "mean_retained_vs_unit": (float(retained[:, ki, i].mean())
                                  if retained is not None else None),
        "mean_sync_cpu_per_cs_us": float(cpu[:, ki, i].mean() * 1e6),
    } for ki, p in enumerate(park_costs) for i in range(V)]

    disc_names = list(dict.fromkeys(v["lock"] for v in disc_variants))
    disc_cols = {d: [i for i, v in enumerate(disc_variants)
                     if v["lock"] == d] for d in disc_names}
    by_park = {}
    for ki, p in enumerate(park_costs):
        by_park[str(p)] = {d: {
            "wins": int(win_kv[ki, cols].sum()),
            "best_variant_mean_ratio":
                float(ratio[:, ki, cols].max(axis=1).mean()),
            "mean_retained_vs_unit":
                (float(retained[:, ki, cols].mean())
                 if retained is not None else None),
            "mean_sync_cpu_per_cs_us":
                float(cpu[:, ki, cols].mean() * 1e6),
        } for d, cols in disc_cols.items()}

    phase = []
    order = sorted(range(len(uniq)),
                   key=lambda ci: (list(park_costs).index(uniq[ci][0]),
                                   uniq[ci][1:]))
    for ci in order:
        p, cs_b, sub_b = uniq[ci]
        counts = {variant_names[i]: int(wins_cells[ci, i])
                  for i in range(V) if wins_cells[ci, i]}
        n = sum(counts.values())
        winner = max(counts, key=counts.get)
        phase.append({"park_cost": p, "cs": cs_b, "sub": sub_b, "n": n,
                      "winner": winner,
                      "win_share": round(counts[winner] / n, 3),
                      "wins_by_variant": counts})

    import jax

    out = {
        "meta": {"backend": backend, "n_scenarios": n_scenarios,
                 "n_park_costs": K, "n_variants": V,
                 "n_configs": C, "n_steps": res.n_steps,
                 "wall_s": round(wall, 2),
                 "n_devices": len(jax.devices()),
                 "sharded": bool(shard) if shard is not None
                 else len(jax.devices()) > 1,
                 "streamed": bool(stream),
                 "configs_per_s": round(C / max(wall, 1e-9), 1),
                 "park_costs": list(park_costs),
                 "variant_names": variant_names},
        "variants": out_variants,
        "park_costs": by_park,
        "phase": phase,
    }
    if stream:
        out["meta"].update(chunk_size=res.chunk_size,
                           n_chunks=res.n_chunks,
                           budget_mb=round(res.budget_mb, 1))
    if verbose:
        print(f"\npark grid: {C} configs ({n_scenarios} "
              f"scenarios x {K} park costs x {V} variants) x "
              f"{res.n_steps} steps in {wall:.1f}s on "
              f"{out['meta']['n_devices']} device(s) "
              f"({out['meta']['configs_per_s']} cfg/s)")
        for p in park_costs:
            rows = by_park[str(p)]
            top = max(rows, key=lambda d: rows[d]["wins"])
            print(f"{p:>9}: top discipline {top} "
                  f"({rows[top]['wins']}/{n_scenarios} wins); "
                  + " ".join(f"{d}:{r['wins']}" for d, r in rows.items()))
    return out


# --------------------------------------------------------------------------
# Coarse -> dense resolution refinement
# --------------------------------------------------------------------------
def refine_grid(nx: int = 16, ny: int = 12, factor: int = 3,
                target_cs: int = 150, backend: str = "ref", seed: int = 0,
                disciplines=LOCK_DISCIPLINE_SET, oracles=LOCK_ORACLES,
                cs_range: tuple = (1e-6, 4e-4), thread_range: tuple = (2, 32),
                max_configs: int = 100_000, mem_mb: float | None = None,
                shard: bool | None = None, verbose: bool = True) -> dict:
    """Two-pass phase-boundary refinement over a regular (CS length x
    thread count) lattice at the paper's fixed machine (``LOCK_CORES``
    cores, short NCS, ``LOCK_WAKE`` wake latency).

    Pass 1 streams a coarse ``ny x nx`` lattice (every point crossed with
    every discipline variant) and takes the per-point winner from the
    on-device :class:`repro.core.stream.CellReduce` win matrix.  Pass 2
    re-streams only the dense sub-lattice points (``factor`` x finer per
    axis) that fall in coarse cells touching a phase boundary — where the
    winner differs from a 4-neighbour — so the dense budget is spent on
    the boundary, not the interior.  Total configs are capped at
    ``max_configs`` (dense points beyond the cap are dropped, reported in
    ``meta``).
    """
    variants = lock_discipline_variants(disciplines, oracles)
    V = len(variants)

    vname = _variant_name

    variant_names = [vname(v) for v in variants]

    def lattice_cols(cs_vals, th_vals):
        """(P,) scenario columns for the row-major cs x threads lattice."""
        cs, th = np.meshgrid(cs_vals, th_vals)          # (len(th), len(cs))
        cs, th = cs.ravel(), th.ravel()
        P = cs.size
        sc = {"threads": th.astype(np.int64),
              "cores": np.full(P, LOCK_CORES, np.int64),
              "cs_hi": cs.astype(np.float64),
              "ncs_hi": np.full(P, LOCK_SHORT[1], np.float64),
              "wake": np.full(P, LOCK_WAKE, np.float64),
              "contention": np.ones(P, np.float64),
              "seed": np.full(P, seed, np.int64)}
        return _product_columns(sc, variants), P

    def winners(cs_vals, th_vals):
        cols, P = lattice_cols(cs_vals, th_vals)
        red = xstream.CellReduce(V, np.arange(P, dtype=np.int32), P)
        res = xstream.sweep_stream(cols, target_cs=target_cs,
                                   backend=backend, shard=shard,
                                   mem_mb=mem_mb, reduce=red,
                                   failures_path=FAILURES_PATH)
        return np.asarray(res.wins).argmax(axis=1), res

    t0 = time.time()
    cs_coarse = np.geomspace(cs_range[0], cs_range[1], nx)
    th_coarse = np.unique(np.rint(np.linspace(
        thread_range[0], thread_range[1], ny)).astype(np.int64))
    ny = len(th_coarse)
    win_c, res_c = winners(cs_coarse, th_coarse)
    grid = win_c.reshape(ny, nx)

    boundary = np.zeros((ny, nx), bool)
    boundary[:, 1:] |= grid[:, 1:] != grid[:, :-1]
    boundary[:, :-1] |= grid[:, 1:] != grid[:, :-1]
    boundary[1:, :] |= grid[1:, :] != grid[:-1, :]
    boundary[:-1, :] |= grid[1:, :] != grid[:-1, :]

    cs_dense = np.geomspace(cs_range[0], cs_range[1], factor * nx)
    th_dense = np.unique(np.rint(np.linspace(
        thread_range[0], thread_range[1], factor * ny)).astype(np.int64))
    # Map every dense point to its enclosing coarse cell (nearest coarse
    # index per axis); keep only points inside boundary cells.
    ix = np.clip(np.searchsorted(np.sqrt(cs_coarse[1:] * cs_coarse[:-1]),
                                 cs_dense), 0, nx - 1)
    iy = np.clip(np.searchsorted((th_coarse[1:] + th_coarse[:-1]) / 2.0,
                                 th_dense), 0, ny - 1)
    keep_y, keep_x = np.nonzero(boundary[np.ix_(iy, ix)])
    pts_cs = cs_dense[keep_x]
    pts_th = th_dense[keep_y]
    budget_pts = max(0, max_configs // V - nx * ny)
    n_dropped = max(0, len(pts_cs) - budget_pts)
    pts_cs, pts_th = pts_cs[:budget_pts], pts_th[:budget_pts]

    dense = []
    res_d = None
    if len(pts_cs):
        P = len(pts_cs)
        sc = {"threads": pts_th.astype(np.int64),
              "cores": np.full(P, LOCK_CORES, np.int64),
              "cs_hi": pts_cs.astype(np.float64),
              "ncs_hi": np.full(P, LOCK_SHORT[1], np.float64),
              "wake": np.full(P, LOCK_WAKE, np.float64),
              "contention": np.ones(P, np.float64),
              "seed": np.full(P, seed, np.int64)}
        cols = _product_columns(sc, variants)
        red = xstream.CellReduce(V, np.arange(P, dtype=np.int32), P)
        res_d = xstream.sweep_stream(cols, target_cs=target_cs,
                                     backend=backend, shard=shard,
                                     mem_mb=mem_mb, reduce=red,
                                     failures_path=FAILURES_PATH)
        win_d = np.asarray(res_d.wins).argmax(axis=1)
        dense = [{"cs_us": round(float(c) * 1e6, 4), "threads": int(t),
                  "winner": variant_names[w]}
                 for c, t, w in zip(pts_cs, pts_th, win_d)]
    wall = time.time() - t0

    C = (nx * ny + len(pts_cs)) * V
    out = {
        "meta": {"backend": backend, "nx": nx, "ny": ny, "factor": factor,
                 "n_variants": V, "n_coarse": nx * ny,
                 "n_dense": len(pts_cs), "n_dense_dropped": n_dropped,
                 "n_configs": C, "wall_s": round(wall, 2),
                 "configs_per_s": round(C / max(wall, 1e-9), 1),
                 "chunk_size": res_c.chunk_size,
                 "budget_mb": round(res_c.budget_mb, 1),
                 "variant_names": variant_names},
        "axes": {"cs_us": [round(c * 1e6, 4) for c in cs_coarse],
                 "threads": [int(t) for t in th_coarse]},
        "coarse": [[variant_names[w] for w in row] for row in grid],
        "dense": dense,
    }
    if verbose:
        print(f"\nrefine grid: {nx}x{ny} coarse + {len(pts_cs)} dense "
              f"boundary points ({C} configs) in {wall:.1f}s; "
              f"{int(boundary.sum())} boundary cells"
              + (f"; {n_dropped} dense points dropped at cap"
                 if n_dropped else ""))
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale batches (<60 s total)")
    ap.add_argument("--backend", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--scenarios", type=int, default=200)
    ap.add_argument("--target-cs", type=int, default=250)
    ap.add_argument("--no-bucket", action="store_true",
                    help="run the scenario sweep as one global-horizon "
                         "batch instead of per-step-count buckets")
    ap.add_argument("--stream", choices=("auto", "on", "off"),
                    default="auto",
                    help="run sweeps chunk-by-chunk under a memory budget "
                         "(auto: stream at >= %d configs)" % STREAM_AUTO)
    ap.add_argument("--mem-mb", type=float, default=None,
                    help="streaming memory budget in MiB (default: "
                         "REPRO_SWEEP_MEM_MB env, else device-derived)")
    ap.add_argument("--out", default="reports/sweep.json")
    args = ap.parse_args(argv)

    stream = {"auto": None, "on": True, "off": False}[args.stream]
    if args.quick:
        f3 = fig3_batched(target_cs=60, seeds=(0,), backend=args.backend)
        sc = scenario(n_scenarios=40, target_cs=50, backend=args.backend,
                      bucket=not args.no_bucket, stream=stream,
                      mem_mb=args.mem_mb)
    else:
        f3 = fig3_batched(target_cs=args.target_cs, backend=args.backend)
        sc = scenario(n_scenarios=args.scenarios,
                      target_cs=args.target_cs, backend=args.backend,
                      bucket=not args.no_bucket, stream=stream,
                      mem_mb=args.mem_mb)

    results = {"fig3": f3, "scenario": sc}
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {args.out}")
    return results


if __name__ == "__main__":
    main()

"""Oracle ablation — the paper's future work ("study other approaches to
resize the spinning window"), §5.

Same DES, same mutable-lock state machine, different EvalSWS replacements:

    paper   — double on late wake-up, −1 after K clean (K=10)
    paper-k3/k30 — K sensitivity (paper: K trades late-wake probability
              ~1/(K+1) against hardware contention)
    aimd    — +1 on late wake-up, halve after K clean (opposite bias:
              favors CPU savings over latency)
    fixed1 / fixed-cores — no adaptation (static windows)

Reported per oracle: throughput ratio to the per-cell optimum and spin
CPU per CS, averaged over the paper's four CS/NCS regimes at 8/16/20/26
threads.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core.des import simulate
from repro.core.oracle import AIMDOracle, EvalSWS, FixedOracle

SHORT = (0.0, 3.7e-6)
LONG = (0.0, 366e-6)
REGIMES = {"ss": (SHORT, SHORT), "ls": (LONG, SHORT),
           "sl": (SHORT, LONG), "ll": (LONG, LONG)}
THREADS = [8, 16, 20, 26]
CORES = 20
WAKE = 8e-6

ORACLES = {
    "paper":   lambda: {"oracle": EvalSWS(k=10)},
    "paper-k3":  lambda: {"oracle": EvalSWS(k=3)},
    "paper-k30": lambda: {"oracle": EvalSWS(k=30)},
    "aimd":    lambda: {"oracle": AIMDOracle(k=10)},
    "fixed1":  lambda: {"oracle": FixedOracle(), "initial_sws": 1},
    "fixed-cores": lambda: {"oracle": FixedOracle(), "initial_sws": CORES},
}


def run(target_cs: int = 1200, seeds=(0, 1)) -> dict:
    out = {}
    for name, mk in ORACLES.items():
        thr_sum = cpu_sum = 0.0
        cells = 0
        per_regime = {}
        for rname, (cs, ncs) in REGIMES.items():
            best = {}
            for tc in THREADS:
                thr = cpu = 0.0
                for seed in seeds:
                    r = simulate("mutable", tc, cores=CORES, cs=cs, ncs=ncs,
                                 wake_latency=WAKE, target_cs=target_cs,
                                 seed=seed, lock_kwargs=mk())
                    thr += r.throughput / len(seeds)
                    cpu += r.sync_cpu_per_cs / len(seeds)
                best[tc] = (thr, cpu)
            per_regime[rname] = best
        out[name] = per_regime
    # normalize: per (regime, tc) optimum across oracles
    table = {}
    for name in ORACLES:
        ratios, cpus = [], []
        for rname in REGIMES:
            for tc in THREADS:
                opt = max(out[o][rname][tc][0] for o in ORACLES)
                ratios.append(out[name][rname][tc][0] / opt)
                cpus.append(out[name][rname][tc][1])
        table[name] = {"mean_ratio_to_opt": sum(ratios) / len(ratios),
                       "mean_sync_cpu_us": 1e6 * sum(cpus) / len(cpus)}
    return table


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target-cs", type=int, default=1200)
    ap.add_argument("--out", default="reports/oracle_ablation.json")
    args = ap.parse_args(argv)
    table = run(args.target_cs)
    print(f"{'oracle':>12} {'ratio-to-opt':>13} {'sync CPU/CS (µs)':>17}")
    for name, row in sorted(table.items(),
                            key=lambda kv: -kv[1]["mean_ratio_to_opt"]):
        print(f"{name:>12} {row['mean_ratio_to_opt']:13.3f} "
              f"{row['mean_sync_cpu_us']:17.1f}")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(table, f, indent=1)
    print(f"wrote {args.out}")
    return table


if __name__ == "__main__":
    main()

"""Oracle ablation — the paper's future work ("study other approaches to
resize the spinning window", §5), run as ONE batched xdes call.

Four SWS-adaptation families (see ``docs/oracles.md`` for rules and
provenance), each swept over its ``(K, sws_max)`` tuning grid on every
random scenario of the adaptive-spin design space:

    paper   — EvalSWS: double on late wake-up, -1 after K clean (E1-E12)
    aimd    — +1 on late wake-up, halve after K clean (Fissile-style
              backoff splitting: favors CPU savings over latency)
    fixed   — no adaptation: window pinned at the retrial budget K
              (glibc ``spin_count`` cap / Oracle RDBMS ``_spin_count``)
    history — EWMA of the late-wake rate (glibc adaptive-mutex smoothing);
              grow above 2x the 1/(K+1) target, shrink below half

The whole ``(oracle, K, sws_max) x scenario`` product is simulated by a
single jit-compiled :func:`repro.core.xdes.simulate_batch` program (no
per-cell Python loop — the sequential-DES version of this benchmark ran
for minutes per family).  Artifacts, also emitted by ``benchmarks/run.py``:

* ``reports/oracle_ablation.json`` — full per-variant / per-family stats
* ``reports/oracle_phase_diagram.csv`` — which family wins per workload
  bucket (CS length x subscription x wake latency)
* ``reports/oracle_phase_diagram.md`` — the same as a readable report

    PYTHONPATH=src python -m benchmarks.oracle_ablation [--quick]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks import sweep


def write_phase_diagram(result: dict, reports_dir: str = "reports",
                        stem: str = "oracle_phase_diagram") -> tuple[str, str]:
    """Render the oracle grid's phase diagram to ``<stem>.csv`` and
    ``<stem>.md`` under ``reports_dir``.  Returns the two paths."""
    os.makedirs(reports_dir, exist_ok=True)
    fam_names = list(result["families"])

    csv_path = os.path.join(reports_dir, stem + ".csv")
    with open(csv_path, "w") as f:
        f.write("cs,subscription,wake,n,winner,win_share,"
                + ",".join(f"wins_{n}" for n in fam_names) + "\n")
        for cell in result["phase"]:
            f.write(f"{cell['cs']},{cell['sub']},{cell['wake']},"
                    f"{cell['n']},{cell['winner']},{cell['win_share']},"
                    + ",".join(str(cell["wins_by_family"][n])
                               for n in fam_names) + "\n")

    md_path = os.path.join(reports_dir, stem + ".md")
    meta = result["meta"]
    with open(md_path, "w") as f:
        f.write("# Oracle phase diagram — which SWS oracle wins where\n\n")
        f.write(f"{meta['n_scenarios']} random scenarios x "
                f"{meta['n_variants']} (oracle, K, sws_max) variants = "
                f"{meta['n_configs']} mutable-lock configurations, one "
                f"batched xdes call ({meta['backend']} backend, "
                f"{meta['n_steps']} steps, {meta['wall_s']}s wall).\n\n"
                "Update rules and tuning guidance: docs/oracles.md.\n\n")
        f.write("## Family summary (best tuning per scenario)\n\n")
        f.write("| family | wins | best-tuned mean ratio-to-best "
                "| mean spin CPU/CS (µs) |\n|---|---|---|---|\n")
        for name, row in result["families"].items():
            f.write(f"| {name} | {row['wins']} "
                    f"| {row['best_tuned_mean_ratio']:.3f} "
                    f"| {row['mean_sync_cpu_per_cs_us']:.2f} |\n")
        f.write("\n## Phase diagram\n\nBuckets: CS length (short ≤ 10 µs "
                "< mid ≤ 100 µs < long), subscription (threads vs cores), "
                "wake latency (fast ≤ 10 µs < slow).\n\n")
        f.write("| CS | subscription | wake | n | winning family "
                "| win share |\n|---|---|---|---|---|---|\n")
        for cell in result["phase"]:
            f.write(f"| {cell['cs']} | {cell['sub']} | {cell['wake']} "
                    f"| {cell['n']} | {cell['winner']} "
                    f"| {cell['win_share']:.2f} |\n")
        f.write("\n## Variant detail\n\n| variant | wins | mean ratio "
                "| p10 ratio | spin CPU/CS (µs) | mean final SWS |\n"
                "|---|---|---|---|---|---|\n")
        for v in sorted(result["variants"],
                        key=lambda v: -v["mean_ratio_to_best"]):
            f.write(f"| {v['name']} | {v['wins']} "
                    f"| {v['mean_ratio_to_best']:.3f} "
                    f"| {v['p10_ratio_to_best']:.3f} "
                    f"| {v['mean_sync_cpu_per_cs_us']:.2f} "
                    f"| {v['mean_final_sws']:.1f} |\n")
    return csv_path, md_path


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale grid (<30 s)")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="default: 200 (24 with --quick)")
    ap.add_argument("--target-cs", type=int, default=None,
                    help="default: 150 (40 with --quick)")
    ap.add_argument("--backend", choices=("ref", "pallas"), default="ref")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", choices=("auto", "on", "off"),
                    default="auto",
                    help="run the grid chunk-by-chunk under a memory "
                         "budget (auto: stream at >= %d configs)"
                         % sweep.STREAM_AUTO)
    ap.add_argument("--mem-mb", type=float, default=None,
                    help="streaming memory budget in MiB (default: "
                         "REPRO_SWEEP_MEM_MB env, else device-derived)")
    ap.add_argument("--out", default="reports/oracle_ablation.json")
    args = ap.parse_args(argv)

    stream = {"auto": None, "on": True, "off": False}[args.stream]
    if args.quick:
        result = sweep.oracle_grid(n_scenarios=args.scenarios or 24,
                                   target_cs=args.target_cs or 40,
                                   backend=args.backend, seed=args.seed,
                                   ks=(3, 10), sws_maxes=(None,),
                                   stream=stream, mem_mb=args.mem_mb)
    else:
        result = sweep.oracle_grid(n_scenarios=args.scenarios or 200,
                                   target_cs=args.target_cs or 150,
                                   backend=args.backend, seed=args.seed,
                                   stream=stream, mem_mb=args.mem_mb)

    # all three artifacts (JSON + CSV + MD) land in the same directory
    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    csv_path, md_path = write_phase_diagram(result, out_dir)
    print(f"wrote {args.out}, {csv_path}, {md_path}")
    return result


if __name__ == "__main__":
    main()

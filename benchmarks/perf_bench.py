"""Wall-clock microbenchmark of the batched lock simulator — the tracked
perf trajectory of the xdes engine.

Five suites, sim cells timed twice (cold = compile + run, steady = the
jit-cached second call; throughputs are computed from the steady time):

* ``dispatch`` — a pinned-horizon 1k-config batch (10k too with
  ``--full-size``) through every (backend, rollout) cell: ``ref``/
  ``pallas`` x per-step ``scan`` (two kernel dispatches per timestep, the
  legacy path) vs time-blocked ``blocked`` (one fused dispatch per
  :data:`repro.core.xdes.DEFAULT_BLOCK_STEPS` timesteps).  Same step
  count everywhere, early exit off — this isolates the dispatch-count
  effect and is the stable cell the CI regression gate checks.
* ``sweep`` — the end-to-end 1k-config scenario sweep at an auto-planned
  horizon: the legacy path (scan, full horizon, one global scan length)
  vs the shipped fast path (blocked + early exit + ``bucket_steps``, so
  a 100µs-CS cell no longer pins a µs-spin cell to its scan length).
* ``open_loop`` — the open-loop arrival engine (request ring, binding,
  on-device latency histograms) vs the closed engine at the same pinned
  horizon: the wall-clock price of per-request tail-latency telemetry.
* ``encode`` — packing 100k configs into engine columns: the per-config
  ``encode_configs_legacy`` lambda table vs the array-native
  ``encode_configs`` column path (the streamed sweeps' feed).
* ``stream`` — the end-to-end streamed discipline sweep
  (:func:`repro.core.stream.sweep_stream`, bucketed, memory-budgeted):
  20k configs in quick mode, 20k + the recorded 100k run in full mode,
  with peak RSS (``ru_maxrss``) alongside the chunk plan.

Artifact: ``BENCH_xdes.json`` at the repo root is the COMMITTED perf
baseline — schema 2: ``{"schema": 2, "entries": {<env>: result}}`` keyed
by ``<platform>/<n_devices>dev/<interpret|compiled>`` so baselines from
different machines coexist and CI compares against ITS OWN environment's
entry (``--check`` passes with a note when the env has no entry yet).
Writes merge into the existing file under the current env key; legacy
single-result files are migrated under their own recorded env.  Ad-hoc
runs default to ``reports/bench_xdes.json`` so a bare invocation can't
clobber the baseline — refresh it deliberately with
``--out BENCH_xdes.json`` (full mode, quiet machine).  How to read it:
docs/performance.md.

    PYTHONPATH=src python -m benchmarks.perf_bench [--quick] [--check]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

#: The regression gate's tolerance: fail if a cell's steady-state
#: throughput drops below baseline / REGRESSION_FACTOR (CI runners and
#: dev boxes differ in speed; 2x catches algorithmic regressions without
#: tripping on machine noise).
REGRESSION_FACTOR = 2.0


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def _time_twice(fn):
    """(cold_s, steady_s, result): first call compiles, second hits the
    jit cache — steady state is what the trajectory tracks."""
    t0 = time.perf_counter()
    fn()
    t1 = time.perf_counter()
    res = fn()
    t2 = time.perf_counter()
    return t1 - t0, t2 - t1, res


def dispatch_suite(n_configs: int, n_steps: int, backends=("ref", "pallas"),
                   verbose: bool = True) -> dict:
    """Pinned-horizon (backend x rollout) grid on one scenario batch."""
    from repro.configs.catalog import lock_scenario_sweep
    from repro.core import xdes

    configs = lock_scenario_sweep(n_scenarios=n_configs // 5)
    assert len(configs) == n_configs
    cells = {}
    for backend in backends:
        for rollout in ("scan", "blocked"):
            cold, steady, res = _time_twice(lambda: xdes.simulate_batch(
                configs, n_steps=n_steps, backend=backend, rollout=rollout))
            cells[f"{backend}/{rollout}"] = {
                "n_configs": n_configs, "n_steps": n_steps,
                "block_steps": (xdes.DEFAULT_BLOCK_STEPS
                                if rollout == "blocked" else 1),
                "wall_cold_s": round(cold, 3), "wall_s": round(steady, 3),
                "cfg_steps_per_s": round(n_configs * n_steps / steady, 1),
            }
            if verbose:
                c = cells[f"{backend}/{rollout}"]
                print(f"  {backend:>6}/{rollout:<7} cold {_fmt_s(cold):>8} "
                      f"steady {_fmt_s(steady):>8} "
                      f"({c['cfg_steps_per_s']:.2e} cfg-steps/s)")
    return cells


def sweep_suite(n_scenarios: int, target_cs: int,
                verbose: bool = True) -> dict:
    """End-to-end auto-planned scenario sweep: legacy full-horizon scan vs
    the shipped fast path (blocked + early exit + bucketing)."""
    from repro.configs.catalog import lock_scenario_sweep
    from repro.core import xdes

    configs = lock_scenario_sweep(n_scenarios=n_scenarios)
    variants = {
        "legacy": dict(rollout="scan", early_exit=False,
                       bucket_steps=False),
        "blocked": dict(rollout="blocked", early_exit=False,
                        bucket_steps=False),
        "fast": dict(rollout="blocked", early_exit=True, bucket_steps=True),
    }
    cells = {}
    for name, kw in variants.items():
        cold, steady, res = _time_twice(lambda: xdes.simulate_batch(
            configs, target_cs=target_cs, **kw))
        run = np.asarray(res.steps_run, np.int64)
        cells[name] = {
            "n_configs": len(configs), "target_cs": target_cs,
            "planned_steps": int(res.n_steps),
            "mean_steps_run": round(float(run.mean()), 1),
            "executed_cfg_steps": int(run.sum()),
            "wall_cold_s": round(cold, 3), "wall_s": round(steady, 3),
            "min_completed": int(res.completed.min()),
        }
        if verbose:
            c = cells[name]
            print(f"  {name:>8} cold {_fmt_s(cold):>8} steady "
                  f"{_fmt_s(steady):>8} (mean steps run "
                  f"{c['mean_steps_run']:.0f} of {c['planned_steps']} "
                  f"planned, min completed {c['min_completed']})")
    return cells


def encode_suite(n_configs: int = 100_000, verbose: bool = True) -> dict:
    """Config packing: per-config lambda table vs array-native columns.

    Both paths pack the SAME sweep (the column twin is bit-equal to the
    list pack, asserted here) — the timed step is encode only; building
    the 100k ``SimConfig`` list for the legacy path is setup, not
    payload.  Best-of-3 wall times: pure numpy, no jit warmup needed."""
    from repro.configs.catalog import (lock_scenario_columns,
                                       lock_scenario_sweep)
    from repro.core import policy

    n_scenarios = n_configs // 5
    configs = lock_scenario_sweep(n_scenarios=n_scenarios)
    cols = lock_scenario_columns(n_scenarios=n_scenarios)

    def best_of(fn, n=3):
        best, res = float("inf"), None
        for _ in range(n):
            t0 = time.perf_counter()
            res = fn()
            best = min(best, time.perf_counter() - t0)
        return best, res

    legacy_s, legacy = best_of(lambda: policy.encode_configs_legacy(configs))
    column_s, packed = best_of(lambda: policy.encode_configs(cols))
    for k in packed:
        assert np.array_equal(packed[k], legacy[k]), f"encode mismatch: {k}"
    cells = {
        "n_configs": len(configs),
        "legacy_s": round(legacy_s, 4), "columns_s": round(column_s, 4),
        "legacy_cfg_per_s": round(len(configs) / legacy_s, 1),
        "columns_cfg_per_s": round(len(configs) / column_s, 1),
        "speedup": round(legacy_s / column_s, 1),
    }
    if verbose:
        print(f"  legacy {_fmt_s(legacy_s):>8}  columns "
              f"{_fmt_s(column_s):>8}  ({cells['speedup']}x)")
    return cells


def stream_suite(n_configs: int, target_cs: int,
                 mem_mb: float | None = None,
                 verbose: bool = True) -> dict:
    """End-to-end streamed discipline sweep: bucketed ``sweep_stream``
    under a memory budget, with peak RSS recorded next to the chunk
    plan.  One cold call — at this scale the compile cost is noise and a
    steady rerun would double a minutes-long cell."""
    import resource

    from repro.configs.catalog import (lock_discipline_columns,
                                       lock_discipline_variants)
    from repro.core import stream as xstream

    V = len(lock_discipline_variants())
    n_scenarios = max(1, n_configs // V)
    cols = lock_discipline_columns(n_scenarios=n_scenarios)
    C = n_scenarios * V
    t0 = time.perf_counter()
    res = xstream.sweep_stream(cols, target_cs=target_cs, backend="ref",
                               bucket_steps=True, mem_mb=mem_mb)
    wall = time.perf_counter() - t0
    rss_kib = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    cell = {
        "n_configs": C, "target_cs": target_cs,
        "wall_s": round(wall, 2),
        "configs_per_s": round(C / wall, 1),
        "chunk_size": res.chunk_size, "n_chunks": res.n_chunks,
        "budget_mb": round(res.budget_mb, 1),
        "bytes_per_config": res.bytes_per_config,
        "ru_maxrss_mb": round(rss_kib / 1024.0, 1),
        "min_completed": int(res.completed.min()),
    }
    if verbose:
        print(f"  {C} configs in {_fmt_s(wall):>8} "
              f"({cell['configs_per_s']} cfg/s, {res.n_chunks} chunk(s) "
              f"of <= {res.chunk_size}, peak RSS "
              f"{cell['ru_maxrss_mb']:.0f} MB)")
    return cell


def open_loop_suite(n_configs: int, n_steps: int,
                    verbose: bool = True) -> dict:
    """Pinned-horizon open-loop cells: the arrival engine (request ring,
    binding, on-device latency histograms) vs the closed engine at the
    same config count and horizon — the wall-clock price of per-request
    tail-latency telemetry.  Both cells run the blocked rollout with
    early exit off; throughput is compared per cfg-step so the slightly
    different variant counts cancel."""
    from repro.configs.catalog import (lock_arrival_sweep,
                                       lock_arrival_variants,
                                       lock_discipline_sweep,
                                       lock_discipline_variants)
    from repro.core import xdes

    Va = len(lock_arrival_variants())
    Vd = len(lock_discipline_variants())
    batches = {
        "closed": lock_discipline_sweep(
            n_scenarios=max(1, n_configs // Vd)),
        "open": lock_arrival_sweep(n_scenarios=max(1, n_configs // Va)),
    }
    cells = {}
    for name, cfgs in batches.items():
        cold, steady, res = _time_twice(lambda: xdes.simulate_batch(
            cfgs, n_steps=n_steps, rollout="blocked", early_exit=False))
        cells[name] = {
            "n_configs": len(cfgs), "n_steps": n_steps,
            "wall_cold_s": round(cold, 3), "wall_s": round(steady, 3),
            "cfg_steps_per_s": round(len(cfgs) * n_steps / steady, 1),
        }
        if verbose:
            c = cells[name]
            print(f"  {name:>7} cold {_fmt_s(cold):>8} steady "
                  f"{_fmt_s(steady):>8} "
                  f"({c['cfg_steps_per_s']:.2e} cfg-steps/s)")
    cells["open_overhead_x"] = round(
        cells["closed"]["cfg_steps_per_s"]
        / max(cells["open"]["cfg_steps_per_s"], 1e-9), 2)
    if verbose:
        print(f"  open-loop overhead {cells['open_overhead_x']}x "
              f"(closed cfg-steps/s over open)")
    return cells


def env_key(meta: dict) -> str:
    """The baseline entry key for one environment's measurements —
    results are only comparable within a (platform, device count,
    pallas-interpret) triple."""
    return (f"{meta['platform']}/{meta['n_devices']}dev/"
            f"{'interpret' if meta['pallas_interpret'] else 'compiled'}")


def load_entries(path: str) -> dict:
    """Read a baseline file as ``{env_key: result}`` — schema-2 files
    verbatim, legacy single-result files keyed by their recorded meta."""
    with open(path) as f:
        data = json.load(f)
    if data.get("schema") == 2:
        return data["entries"]
    return {env_key(data["meta"]): data}


def _speedups(cells: dict) -> dict:
    out = {}
    for backend in ("ref", "pallas"):
        a, b = cells.get(f"{backend}/scan"), cells.get(f"{backend}/blocked")
        if a and b:
            out[f"dispatch/{backend}/blocked_over_scan"] = round(
                a["wall_s"] / b["wall_s"], 2)
    return out


def summarize(result: dict) -> str:
    """Markdown perf table (the roofline report's table style, repointed
    at the xdes trajectory)."""
    lines = ["### xdes perf trajectory — `BENCH_xdes.json`", "",
             "| cell | configs | steps | cold | steady | cfg-steps/s |",
             "|---|---|---|---|---|---|"]
    for name, c in result["dispatch"].items():
        lines.append(
            f"| dispatch {name} | {c['n_configs']} | {c['n_steps']} "
            f"| {_fmt_s(c['wall_cold_s'])} | {_fmt_s(c['wall_s'])} "
            f"| {c['cfg_steps_per_s']:.2e} |")
    for name, c in result["sweep"].items():
        lines.append(
            f"| sweep {name} | {c['n_configs']} "
            f"| {c['mean_steps_run']:.0f}/{c['planned_steps']} "
            f"| {_fmt_s(c['wall_cold_s'])} | {_fmt_s(c['wall_s'])} | - |")
    for name in ("closed", "open"):
        c = result.get("open_loop", {}).get(name)
        if c:
            lines.append(
                f"| open_loop {name} | {c['n_configs']} | {c['n_steps']} "
                f"| {_fmt_s(c['wall_cold_s'])} | {_fmt_s(c['wall_s'])} "
                f"| {c['cfg_steps_per_s']:.2e} |")
    for name, c in result.get("stream", {}).items():
        lines.append(
            f"| stream {name} | {c['n_configs']} | - "
            f"| - | {_fmt_s(c['wall_s'])} | {c['configs_per_s']} cfg/s, "
            f"{c['n_chunks']} chunks, RSS {c['ru_maxrss_mb']:.0f} MB |")
    enc = result.get("encode")
    if enc:
        lines.append(
            f"| encode columns | {enc['n_configs']} | - "
            f"| - | {_fmt_s(enc['columns_s'])} "
            f"| {enc['speedup']}x over legacy |")
    lines += ["", "| speedup | x |", "|---|---|"]
    for k, v in result["speedups"].items():
        lines.append(f"| {k} | {v} |")
    return "\n".join(lines)


def check_regression(result: dict, baseline: dict,
                     factor: float = REGRESSION_FACTOR) -> list[str]:
    """Compare steady-state throughput of matching dispatch and stream
    cells against the committed baseline (one environment's entry);
    return the list of failures (empty = pass)."""
    failures = []
    base_cells = baseline.get("dispatch", {})
    for name, cell in result.get("dispatch", {}).items():
        base = base_cells.get(name)
        if not base or (base["n_configs"], base["n_steps"]) != (
                cell["n_configs"], cell["n_steps"]):
            continue                      # different scale: not comparable
        if cell["cfg_steps_per_s"] * factor < base["cfg_steps_per_s"]:
            failures.append(
                f"{name}: {cell['cfg_steps_per_s']:.2e} cfg-steps/s is "
                f">{factor}x below baseline "
                f"{base['cfg_steps_per_s']:.2e}")
    base_stream = baseline.get("stream", {})
    for name, cell in result.get("stream", {}).items():
        base = base_stream.get(name)
        if not base or (base["n_configs"], base["target_cs"]) != (
                cell["n_configs"], cell["target_cs"]):
            continue
        if cell["configs_per_s"] * factor < base["configs_per_s"]:
            failures.append(
                f"stream {name}: {cell['configs_per_s']} cfg/s is "
                f">{factor}x below baseline {base['configs_per_s']}")
    return failures


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-scale: 1k-config dispatch grid + 200-config "
                         "sweep (<90 s on CPU)")
    ap.add_argument("--full-size", action="store_true",
                    help="add the 10k-config dispatch cell (ref backend)")
    ap.add_argument("--out", default="reports/bench_xdes.json",
                    help="output path; pass --out BENCH_xdes.json (repo "
                         "root) to deliberately refresh the committed "
                         "baseline the CI gate compares against")
    ap.add_argument("--check", action="store_true",
                    help="compare against this environment's entry in the "
                         "committed baseline at --baseline BEFORE "
                         "overwriting; exit 1 on a "
                         f">{REGRESSION_FACTOR}x throughput regression")
    ap.add_argument("--baseline", default="BENCH_xdes.json")
    ap.add_argument("--mem-mb", type=float, default=None,
                    help="streaming suite memory budget in MiB (default: "
                         "REPRO_SWEEP_MEM_MB env, else device-derived)")
    args = ap.parse_args(argv)

    baseline_entries = None
    if args.check:
        # fail fast: --check with no baseline must not pass silently (a
        # deleted or misplaced BENCH_xdes.json would disarm the CI gate)
        if not os.path.exists(args.baseline):
            raise SystemExit(
                f"perf check: no baseline at {args.baseline} "
                f"(refresh it with --out BENCH_xdes.json)")
        baseline_entries = load_entries(args.baseline)

    import jax

    from repro.kernels.pallas_compat import default_interpret

    t0 = time.time()
    print("dispatch suite (pinned horizon, early exit off):")
    dispatch = dispatch_suite(1000, 384)
    if args.full_size:
        print("dispatch suite, 10k configs (ref backend):")
        dispatch.update({f"10k-{k}": v for k, v in dispatch_suite(
            10_000, 384, backends=("ref",)).items()})

    print("sweep suite (auto-planned horizon):")
    sweep = sweep_suite(n_scenarios=40 if args.quick else 200,
                        target_cs=20 if args.quick else 50)

    print("open-loop suite (pinned horizon, arrival engine vs closed):")
    open_loop = open_loop_suite(1000, 384)

    print("encode suite (100k-config packing):")
    encode = encode_suite(100_000)

    print("stream suite (bucketed sweep_stream under a memory budget):")
    stream = {"discipline_20k": stream_suite(20_000, target_cs=20,
                                             mem_mb=args.mem_mb)}
    if not args.quick:
        stream["discipline_100k"] = stream_suite(100_000, target_cs=20,
                                                 mem_mb=args.mem_mb)

    result = {
        "meta": {
            "platform": jax.default_backend(),
            "n_devices": len(jax.devices()),
            "jax": jax.__version__,
            "pallas_interpret": bool(default_interpret()),
            "mode": "quick" if args.quick else "full",
            "wall_total_s": None,
        },
        "dispatch": dispatch,
        "sweep": sweep,
        "open_loop": open_loop,
        "encode": encode,
        "stream": stream,
    }
    result["speedups"] = _speedups(dispatch)
    result["speedups"]["open_loop/overhead_x"] = open_loop[
        "open_overhead_x"]
    legacy, fast = sweep.get("legacy"), sweep.get("fast")
    if legacy and fast:
        result["speedups"]["sweep/fast_over_legacy"] = round(
            legacy["wall_s"] / fast["wall_s"], 2)
    result["speedups"]["encode/columns_over_legacy"] = encode["speedup"]
    result["meta"]["wall_total_s"] = round(time.time() - t0, 1)

    key = env_key(result["meta"])
    entries = load_entries(args.out) if os.path.exists(args.out) else {}
    entries[key] = result
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"schema": 2, "entries": entries}, f, indent=1)
        f.write("\n")
    print(f"\n{summarize(result)}\n\nwrote {args.out} entry '{key}' "
          f"({result['meta']['wall_total_s']}s total)")

    if baseline_entries is not None:
        base = baseline_entries.get(key)
        if base is None:
            print(f"perf check vs {args.baseline}: no entry for '{key}' "
                  f"yet — nothing to compare (refresh the baseline on "
                  f"this environment to arm the gate)")
        else:
            failures = check_regression(result, base)
            if failures:
                print("PERF REGRESSION vs committed baseline:")
                for line in failures:
                    print(f"  {line}")
                raise SystemExit(1)
            print(f"perf check vs {args.baseline} entry '{key}': OK "
                  f"(no cell >{REGRESSION_FACTOR}x below baseline)")
    return result


if __name__ == "__main__":
    main()

"""Roofline report: reads reports/dryrun/*/*.json -> markdown tables for
EXPERIMENTS.md §Dry-run and §Roofline.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh pod1] [--tag ...]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = ["gemma3-4b", "llama3.2-1b", "qwen2.5-14b", "stablelm-3b",
              "granite-moe-1b-a400m", "qwen3-moe-235b-a22b",
              "jamba-1.5-large-398b", "chameleon-34b", "rwkv6-1.6b",
              "whisper-large-v3"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(root: str = "reports/dryrun", mesh: str = "pod1",
               tag: str = "") -> dict:
    cells = {}
    for path in glob.glob(os.path.join(root, mesh, "*.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag:
            continue
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def roofline_table(cells: dict, mesh: str) -> str:
    lines = [
        f"### Roofline — mesh `{mesh}` (terms in per-step seconds; "
        "v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | compute | memory | collective | dominant | "
        "useful (6ND/HLO) | roofline frac | HBM/dev | status |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = cells.get((arch, shape))
            if rec is None:
                continue
            if rec["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - "
                             f"| - | skipped: {rec['reason'][:50]} |")
                continue
            if rec["status"] != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - "
                             f"| - | FAILED |")
                continue
            r = rec["roofline"]
            mem = rec["memory"]["peak_bytes_per_device"] / 2**30
            lines.append(
                f"| {arch} | {shape} | {_fmt_s(r['compute_s'])} "
                f"| {_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} "
                f"| **{r['dominant']}** | {r['useful_ratio']:.3f} "
                f"| {r['roofline_fraction']:.4f} | {mem:.1f} GiB "
                f"| ok |")
    return "\n".join(lines)


def dryrun_table(cells: dict, mesh: str) -> str:
    lines = [
        f"### Dry-run — mesh `{mesh}`",
        "",
        "| arch | shape | chips | compile | HBM/dev | args/dev | HLO flops/dev "
        "| collective ops | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = cells.get((arch, shape))
            if rec is None:
                continue
            if rec["status"] != "ok":
                lines.append(
                    f"| {arch} | {shape} | - | - | - | - | - | - "
                    f"| {rec['status']} |")
                continue
            m = rec["memory"]
            colls = rec["roofline"]["collective_count"]
            ctxt = ", ".join(f"{k}×{v}" for k, v in sorted(colls.items())
                             if not k.endswith("(g=1)"))[:80]
            lines.append(
                f"| {arch} | {shape} | {rec['n_chips']} "
                f"| {rec.get('compile_s', '-')}s "
                f"| {m['peak_bytes_per_device']/2**30:.1f} GiB "
                f"| {m['argument_bytes']/2**30:.2f} GiB "
                f"| {rec['roofline']['flops']:.2e} | {ctxt} | ok |")
    return "\n".join(lines)


def summarize(root: str = "reports/dryrun") -> str:
    parts = []
    for mesh in ("pod1", "pod2"):
        for tag in ("", "v2"):
            cells = load_cells(root, mesh, tag)
            if not cells:
                continue
            label = f"{mesh}" + (f" (optimized `{tag}`)" if tag else
                                 " (paper-faithful baseline)")
            parts.append(dryrun_table(cells, label))
            parts.append("")
            if mesh == "pod1":  # roofline table is single-pod per assignment
                parts.append(roofline_table(cells, label))
                parts.append("")
    return "\n".join(parts)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="reports/dryrun")
    ap.add_argument("--out", default="reports/roofline.md")
    args = ap.parse_args(argv)
    text = summarize(args.root)
    with open(args.out, "w") as f:
        f.write(text)
    print(text)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()

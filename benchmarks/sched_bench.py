"""Serving-window benchmark: the paper's oracle governing decode-batch
admission (DESIGN.md §3.2) — the TPU-native embodiment of the technique.

Workload: bursty arrivals into a slot-based decode engine.  The standby
pool (prefilled-ahead requests) is the spinning window:

    window = 0      -> pure "sleep lock": every handoff pays prefill openly
    window = max    -> pure "spin lock": max standby KV held at all times
    EvalSWS         -> the paper's self-tuned window

Metrics mirror the paper's two axes:
    late_handoff_rate  — responsiveness (paper: CS-access latency)
    avg_standby        — resource waste (paper: spin CPU), in KV-slots held

Claim validated: the mutable window reaches a late-handoff rate close to
the window=max policy while holding a standby pool closer to window=0 —
i.e. it buys spin-level latency at a fraction of the resource cost, under
a workload it was not tuned for.  (Asserted in tests/test_paper_claims.py.)
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.serve import ContinuousBatcher, Request, SimulatedEngine


def bursty_workload(n_requests: int = 400, seed: int = 0):
    """Arrival pattern with phase shifts: calm -> burst -> calm."""
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    for i in range(n_requests):
        phase = (i // 50) % 3
        rate = (20.0, 200.0, 60.0)[phase]           # arrivals per sec
        t += rng.exponential(1.0 / rate)
        reqs.append((t, Request(
            rid=i, prompt=[1] * int(rng.integers(4, 64)),
            max_new_tokens=int(rng.integers(8, 48)), arrived_at=t)))
    return reqs


def run_policy(policy: str, max_slots: int = 16, max_standby: int = 16,
               n_requests: int = 400, seed: int = 0) -> dict:
    eng = SimulatedEngine(max_slots=max_slots, prefill_cost=8e-3,
                          step_base=2e-3, step_per_slot=2e-4)
    bat = ContinuousBatcher.from_policy(eng, policy, max_standby=max_standby)
    reqs = bursty_workload(n_requests, seed)
    i = 0
    while i < len(reqs) or not bat.idle():
        while i < len(reqs) and reqs[i][0] <= eng.now:
            bat.submit(reqs[i][1])
            i += 1
        if bat.idle():                       # engine idle: jump to arrival
            eng.now = max(eng.now, reqs[i][0])
            continue
        bat.run_step()
    s = bat.stats.summary()
    s["policy"] = policy
    s["makespan_s"] = round(eng.now, 3)
    return s


def xdes_sweep(n_scenarios: int = 100, target_cs: int = 150,
               backend: str = "ref", workload: str = "constant") -> dict:
    """The same zero/max/mutable ablation driven THROUGH xdes: slot/standby
    dynamics encoded on the SimConfig row schema
    (:class:`repro.serve.SchedScenario`) and swept on-device as one
    batched call — scheduler policies ride the same engine as the lock
    disciplines.  ``workload`` selects a hold-time row (e.g. ``bursty``
    for wave-like admission, ``hetero`` for mixed decode lengths) on the
    SAME machines as the constant sweep."""
    from repro.serve import sample_sched_scenarios, xdes_policy_sweep

    return xdes_policy_sweep(
        sample_sched_scenarios(n_scenarios, workload=workload),
        target_cs=target_cs, backend=backend, verbose=True)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--xdes", action="store_true",
                    help="run the ablation through the batched xdes engine "
                         "(one device call) instead of the step-level "
                         "engine simulator")
    ap.add_argument("--scenarios", type=int, default=100,
                    help="scenario count for --xdes")
    ap.add_argument("--workload", default="constant",
                    choices=("constant", "bursty", "hetero", "jitter"),
                    help="hold-time row for --xdes scenarios "
                         "(bursty = wave-like admission)")
    ap.add_argument("--out", default="reports/sched_bench.json")
    args = ap.parse_args(argv)
    if args.xdes:
        out = xdes_sweep(n_scenarios=args.scenarios,
                         workload=args.workload)
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.out}")
        return out["policies"]
    out = {}
    print(f"{'policy':>8} {'late-handoff':>13} {'avg standby':>12} "
          f"{'avg queue':>10} {'makespan':>9}")
    for policy in ("zero", "max", "mutable"):
        rows = [run_policy(policy, n_requests=args.requests, seed=s)
                for s in (0, 1, 2)]
        agg = {k: float(np.mean([r[k] for r in rows]))
               for k in ("late_handoff_rate", "avg_standby", "avg_queue",
                         "makespan_s", "completed")}
        out[policy] = agg
        print(f"{policy:>8} {agg['late_handoff_rate']:13.3f} "
              f"{agg['avg_standby']:12.2f} {agg['avg_queue']:10.2f} "
              f"{agg['makespan_s']:9.3f}")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()

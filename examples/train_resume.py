"""End-to-end training with failure injection + resume (deliverable b).

    PYTHONPATH=src python examples/train_resume.py

Trains a tiny llama, kills it mid-run, restarts from the last atomic
checkpoint, and verifies the loss trajectory continues (bit-identical data
stream across the restart).
"""

import tempfile

from repro.launch.train import main as train_main

with tempfile.TemporaryDirectory() as d:
    print("=== phase 1: train, die at step 18 (ckpt every 10) ===")
    r1 = train_main(["--arch", "llama3.2-1b", "--tiny", "--steps", "30",
                     "--batch", "4", "--seq", "64", "--ckpt-dir", d,
                     "--ckpt-every", "10", "--fail-at", "18"])
    assert r1["died_at"] == 18
    print("\n=== phase 2: restart, resume from step 10, finish ===")
    r2 = train_main(["--arch", "llama3.2-1b", "--tiny", "--steps", "30",
                     "--batch", "4", "--seq", "64", "--ckpt-dir", d,
                     "--ckpt-every", "10"])
    assert "losses" in r2 and len(r2["losses"]) == 20   # steps 10..29
    print("\nresume OK — training is crash-safe.")

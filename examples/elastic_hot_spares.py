"""Hot-spare pool sized by the paper's oracle — fault tolerance at 1000+
nodes (DESIGN.md §3, runtime/elastic.py).

Simulates a year of cluster operation with a time-varying failure rate
(quiet weeks, then a bad batch of machines) and compares three policies:

    cold-only    — no hot spares (pure sleep lock): every failure pays the
                   full provision+restore latency
    always-max   — max hot spares (pure spin lock): instant recovery,
                   maximum reserved capacity
    mutable      — the paper's window: doubles after an exposed failure,
                   decays after K masked ones

    PYTHONPATH=src python examples/elastic_hot_spares.py
"""

import numpy as np

from repro.core.oracle import EvalSWS, FixedOracle
from repro.runtime import ElasticMesh, HotSparePool

HOT_S, COLD_S = 30.0, 600.0
DAY = 86_400.0


def simulate(policy: str, seed: int = 0, days: int = 365) -> dict:
    rng = np.random.default_rng(seed)
    if policy == "cold-only":
        pool = HotSparePool(16, initial=0, oracle=FixedOracle(),
                            hot_spinup_s=HOT_S, cold_spinup_s=COLD_S)
    elif policy == "always-max":
        pool = HotSparePool(16, initial=16, oracle=FixedOracle(),
                            hot_spinup_s=HOT_S, cold_spinup_s=COLD_S)
    else:
        pool = HotSparePool(16, initial=1, oracle=EvalSWS(k=10),
                            hot_spinup_s=HOT_S, cold_spinup_s=COLD_S)
    t = 0.0
    warm_at: list[float] = []
    while t < days * DAY:
        # failure rate: 0.5/day baseline, 6/day during "bad batches"
        bad = (int(t / DAY) % 60) < 5
        rate = (6.0 if bad else 0.5) / DAY
        dt = rng.exponential(1.0 / rate)
        t += dt
        pool.tick(dt)
        # spares that finished warming before this failure
        ready = [w for w in warm_at if w <= t]
        if ready:
            pool.on_spare_ready(len(ready))
            warm_at = [w for w in warm_at if w > t]
        before = pool.cold_queue
        pool.on_failure()
        for _ in range(pool.cold_queue - before):
            warm_at.append(t + COLD_S)
    s = pool.stats
    return {
        "policy": policy,
        "failures": s.failures,
        "exposed": s.exposed,
        "mean_recovery_s": s.recovery_s_total / max(1, s.failures),
        "hot_host_days": s.hot_host_seconds / DAY,
        "window_tail": s.window_trace[-5:] if s.window_trace else [],
    }


def main():
    em = ElasticMesh(chips_per_host=4, model_axis=16, global_batch=256)
    plan = em.plan(61)
    print(f"[re-mesh] 61 healthy hosts -> mesh {plan.shape} "
          f"(accum x{em.accum_for(plan)} keeps the global batch)\n")
    print(f"{'policy':>12} {'failures':>9} {'exposed':>8} "
          f"{'mean recovery':>14} {'hot host-days':>14}")
    rows = {}
    for policy in ("cold-only", "always-max", "mutable"):
        r = simulate(policy)
        rows[policy] = r
        print(f"{policy:>12} {r['failures']:9d} {r['exposed']:8d} "
              f"{r['mean_recovery_s']:13.0f}s {r['hot_host_days']:14.1f}")
    mut, cold, mx = rows["mutable"], rows["cold-only"], rows["always-max"]
    assert mut["mean_recovery_s"] < 0.5 * cold["mean_recovery_s"]
    assert mut["hot_host_days"] < 0.7 * mx["hot_host_days"]
    print("\nmutable window: near always-max recovery at a fraction of the "
          "reserved capacity — the paper's trade-off, at cluster scale.")
    return rows


if __name__ == "__main__":
    main()

"""Window-scheduled serving of a real (tiny) model — deliverable b.

    PYTHONPATH=src python examples/serve_continuous_batching.py

Compares the three admission policies on the same engine (paper §4's
spin/sleep/static-vs-mutable comparison, on TPU-batch admission).
"""

from repro.launch.serve import main as serve_main

for policy in ("zero", "max", "mutable"):
    print(f"\n=== policy: {policy} ===")
    serve_main(["--arch", "llama3.2-1b", "--tiny", "--requests", "12",
                "--slots", "3", "--max-new", "6", "--policy", policy])

"""Quickstart: the whole stack in ~60 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. a MutableLock protecting a shared counter (the paper's primitive),
2. the DES reproducing the paper's Fig. 1 claim,
3. a tiny llama training for a few steps (optimizer + data pipeline),
4. greedy decoding through the window-scheduled serving engine.
"""

import threading
import time

import jax
import numpy as np

# --------------------------------------------------------------- 1. the lock
from repro.core import MutableLock

lock = MutableLock(max_sws=4, record_stats=True)
counter = 0


def bump(n):
    global counter
    for _ in range(n):
        with lock:
            counter += 1


threads = [threading.Thread(target=bump, args=(500,)) for _ in range(4)]
for t in threads:
    t.start()
for t in threads:
    t.join()
assert counter == 2000
print(f"[lock] 4 threads x 500 increments -> {counter} "
      f"(sleeps={lock.stats.sleeps}, late wake-ups="
      f"{lock.stats.late_wakeups}, final sws={lock.sws})")

# ------------------------------------------------------------- 2. Fig 1 DES
from repro.core.des import simulate

unit = 10e-6
res = {}
for kind, kw in (("ttas", {}), ("sleep", {}), ("mutable", {"initial_sws": 2})):
    r = simulate(kind, threads=3, cores=3, cs=(unit, unit), ncs=(1e-9, 1e-9),
                 wake_latency=unit, target_cs=3, max_cs_per_thread=1,
                 seed=1, lock_kwargs=kw)
    res[kind] = r.t_end / unit
print(f"[fig1] slots for 3 CSes — spin {res['ttas']:.1f}, "
      f"sleep {res['sleep']:.1f}, mutable {res['mutable']:.1f} "
      f"(paper: 3 / 5 / 3)")

# ------------------------------------------------------------- 3. train tiny
from repro.configs import base as cbase
from repro.configs.catalog import tiny
from repro.configs.inputs import concrete_batch
from repro.train import TrainConfig, init_state, make_train_step

cfg = tiny(cbase.get_config("llama3.2-1b"))
tcfg = TrainConfig(warmup_steps=5, decay_steps=50)
state = init_state(cfg, tcfg, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(cfg, tcfg))
batch = concrete_batch(cfg, 4, 32, jax.random.PRNGKey(1))
t0 = time.time()
first = last = None
for i in range(8):
    state, m = step(state, batch)
    first = first if first is not None else float(m["loss"])
    last = float(m["loss"])
print(f"[train] tiny llama3.2: loss {first:.3f} -> {last:.3f} "
      f"in 8 steps ({time.time()-t0:.1f}s)")

# ------------------------------------------------------------- 4. serve tiny
from repro import models
from repro.serve import ContinuousBatcher, DecodeEngine, Request

engine = DecodeEngine(cfg, state["params"], max_slots=3, max_seq=32)
bat = ContinuousBatcher(engine, initial=1)
rng = np.random.default_rng(0)
for i in range(6):
    bat.submit(Request(rid=i, prompt=list(rng.integers(2, 200, 5)),
                       max_new_tokens=6))
stats = bat.run_until_drained(max_steps=300).summary()
print(f"[serve] {stats['completed']} requests, late-handoff rate "
      f"{stats['late_handoff_rate']:.2f}, avg standby "
      f"{stats['avg_standby']:.2f}")
print("quickstart OK")
